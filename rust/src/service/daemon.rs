//! The collective service daemon: a long-lived owner of one
//! [`Communicator`] that accepts concurrent client connections, admits
//! their requests into shared traffic-plane batches under explicit
//! admission control, and bills every tenant out of the batch report.
//!
//! ## Threads
//!
//! * one **accept** thread polls the (nonblocking) listener and spawns
//!   a handler thread per connection;
//! * one **handler** thread per connection does the hello exchange,
//!   then reads request frames with an idle-tolerant deadline — a
//!   timeout *before* a frame's first byte is an idle client (keep
//!   waiting, check shutdown), a timeout *mid*-frame is a slow-loris
//!   stall (drop the connection, count it) — so one stalled client
//!   never blocks the others;
//! * one **batcher** thread owns the `Communicator`: it sleeps a short
//!   gather window once work arrives, drains up to
//!   [`ServiceConfig::batch_max`] jobs, tags each with its tenant
//!   ([`crate::comm::TrafficEngine::for_tenant`]), runs them as ONE
//!   overlapped batch under the cross-op port ledger, and writes each
//!   job's reply. Per-op failures surface on that op's reply while
//!   co-batched ops complete — the traffic plane's contract.
//!
//! ## Admission control
//!
//! The queue between handlers and the batcher is bounded
//! ([`ServiceConfig::queue_cap`]). A request arriving at a full queue
//! is refused *immediately* with a `retry_after` hint — it never
//! blocks, never evicts admitted work — and the refusal is charged to
//! the tenant's usage row on the next batch report
//! ([`crate::comm::BatchReport::note_rejected`]).

use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener};
use std::os::unix::net::UnixListener;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::comm::chaos::FaultPlan;
use crate::comm::membership::{elastic_bcast, CrashPlan, Membership};
use crate::comm::rank::TransportKind;
use crate::comm::socket::{fill, read_raw_frame, Stream, MAX_FRAME};
use crate::comm::{CommBuilder, Communicator, OpReport, TenantUsage, TrafficEngine, WireFaults};
use crate::testkit::{submit_mix_op, MixOp, MixPending};

use super::wire::{
    parse_chello, parse_req, res_err_frame, res_ok_frame, res_reject_frame, shello_frame,
    stats_res_frame, summarize, FT_BYE, FT_CHELLO, FT_REQ, FT_SHUTDOWN, FT_STATS,
};

/// Daemon-side cap on a request's payload scale (elements): payloads
/// are *derived*, not shipped, so this bounds the daemon's own memory,
/// not the wire.
pub const MAX_OP_M: usize = 1 << 16;

/// Knobs of the collective service daemon.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Machine size of the daemon's communicator.
    pub p: usize,
    /// Bound on the handler→batcher queue; requests beyond it are
    /// refused with a retry hint (admission control).
    pub queue_cap: usize,
    /// Max ops drained into one batch.
    pub batch_max: usize,
    /// How long the batcher waits after the first job arrives, so
    /// concurrent clients land in the same batch.
    pub gather: Duration,
    /// The backoff hint sent with an admission refusal.
    pub retry_after: Duration,
    /// Mid-frame read deadline per connection — the slow-loris cutoff.
    pub client_timeout: Duration,
    /// Scoped-thread override for batch execution (`None` = the
    /// engine's default rule).
    pub threads: Option<usize>,
    /// Deterministic fault knob for the recovery path:
    /// `Some((rank, during_batch))` kills **global** rank `rank` while
    /// batch number `during_batch` (0-indexed) is in flight. The batch
    /// first runs on the current world; the batcher then replays ONLY
    /// the ops the death actually disrupts
    /// ([`crate::comm::BatchReport::restart_set`]: failed ops plus
    /// every op whose dense window contains the victim) — it shrinks
    /// its [`Membership`], rebuilds the communicator at `p − 1`, remaps
    /// the disrupted jobs' windows and roots into the surviving dense
    /// frame (an op whose window lost every rank gets an error reply),
    /// and bills each replayed op as [`TenantUsage::restarted`].
    /// Completed ops on windows disjoint from the victim keep their
    /// first-run results and are billed exactly once. This is the
    /// in-process stand-in for a rank process dying mid-service (the
    /// multi-process analogue is exercised by the `cbcastd rank` CI
    /// smoke).
    pub fault: Option<(usize, usize)>,
    /// Deterministic **transient**-fault knob: a seeded frame-level
    /// [`FaultPlan`] the daemon self-probes at startup. Before serving,
    /// the daemon runs one small broadcast over a chaos-socket world
    /// under this plan with a zero shrink budget, and refuses to start
    /// if the protocol-v3 reliability layer cannot heal the injected
    /// faults (e.g. a blackholed link that exhausts the retry budget).
    /// Whatever the probe healed is recorded in **this daemon's own**
    /// wire counters ([`ServiceMetrics::wire`], the stats line) —
    /// scoped to the probe's world, so co-resident daemons report
    /// independently. `None` = no probe. Unlike
    /// [`ServiceConfig::fault`], a passing chaos plan consumes **no**
    /// membership epoch — that distinction is the chaos plane's whole
    /// point.
    pub chaos: Option<FaultPlan>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            p: 32,
            queue_cap: 128,
            batch_max: 64,
            gather: Duration::from_millis(2),
            retry_after: Duration::from_millis(5),
            client_timeout: Duration::from_secs(2),
            threads: None,
            fault: None,
            chaos: None,
        }
    }
}

/// A counters snapshot ([`ServiceHandle::metrics`]). Cumulative over
/// the daemon's lifetime; the per-tenant rows fold in one
/// [`TenantUsage`] per label across every batch.
#[derive(Debug, Clone, Default)]
pub struct ServiceMetrics {
    /// Connections accepted.
    pub connections: usize,
    /// Requests admitted into the queue.
    pub admitted: usize,
    /// Requests refused at admission (queue full).
    pub rejected: usize,
    /// Ops that completed with an `Ok` outcome.
    pub completed: usize,
    /// Ops that failed (malformed, oversized, or a runtime error).
    pub failed: usize,
    /// Batches executed.
    pub batches: usize,
    /// Connections dropped for protocol violations or slow-loris
    /// stalls.
    pub dropped: usize,
    /// Membership recoveries performed: each one shrank the world by a
    /// dead rank and rebuilt the communicator for the survivors.
    pub recoveries: usize,
    /// The batcher's current membership epoch (0 = the original,
    /// never-shrunk world; advances once per recovery).
    pub epoch: u64,
    /// **This daemon's** reliable-delivery counters: transient wire
    /// faults healed in place (or escalated) by the protocol-v3 socket
    /// endpoints of this daemon's own worlds — today that is the chaos
    /// self-probe's world ([`ServiceConfig::chaos`]; the batcher's
    /// in-process communicator has no wire). Scoped per daemon — two
    /// daemons in one process account independently; the process-wide
    /// debug aggregate stays available as
    /// [`crate::comm::global_wire_faults`].
    pub wire: WireFaults,
    /// Cumulative per-tenant usage.
    pub tenants: Vec<TenantUsage>,
}

/// One admitted request waiting for the batcher.
struct Job {
    tenant: Arc<str>,
    spec: MixOp,
    req_id: u64,
    /// The connection's write half, shared with its handler thread.
    reply: Arc<Mutex<Stream>>,
}

/// State shared by every daemon thread.
struct Inner {
    cfg: ServiceConfig,
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    stop: AtomicBool,
    metrics: Mutex<ServiceMetrics>,
    /// Per-tenant admission refusals since the last batch — drained
    /// into the next [`crate::comm::BatchReport`].
    rejects: Mutex<HashMap<String, usize>>,
    /// Handler threads, joined at [`ServiceHandle::join`].
    conns: Mutex<Vec<JoinHandle<()>>>,
    /// The TCP bound address, when serving TCP.
    addr: Option<SocketAddr>,
    /// The UDS path, removed on join, when serving UDS.
    uds_path: Option<PathBuf>,
}

impl Inner {
    fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    fn request_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }
}

/// A running daemon ([`serve_unix`] / [`serve_tcp`]). Call
/// [`ServiceHandle::shutdown`] then [`ServiceHandle::join`] for a
/// programmatic stop, or `join` alone to block until a client sends
/// the administrative shutdown frame.
pub struct ServiceHandle {
    inner: Arc<Inner>,
    threads: Vec<JoinHandle<()>>,
}

impl ServiceHandle {
    /// The bound TCP address (`None` when serving UDS) — useful with
    /// `serve_tcp("127.0.0.1:0", …)`.
    pub fn addr(&self) -> Option<SocketAddr> {
        self.inner.addr
    }

    /// Machine size of the daemon's communicator.
    pub fn p(&self) -> usize {
        self.inner.cfg.p
    }

    /// A counters snapshot.
    pub fn metrics(&self) -> ServiceMetrics {
        self.inner.metrics.lock().unwrap().clone()
    }

    /// Ask every daemon thread to wind down (returns immediately).
    pub fn shutdown(&self) {
        self.inner.request_stop();
    }

    /// Block until the daemon stops — immediately after
    /// [`ServiceHandle::shutdown`], or when a client sends the
    /// administrative shutdown frame. Joins every thread (handlers
    /// finish within one idle-poll tick), removes the UDS socket file,
    /// and returns the final counters — replies are written *before*
    /// the batcher folds its counters, so only this post-join snapshot
    /// is guaranteed to account for every reply a client has seen.
    pub fn join(mut self) -> ServiceMetrics {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        let conns = std::mem::take(&mut *self.inner.conns.lock().unwrap());
        for t in conns {
            let _ = t.join();
        }
        if let Some(path) = &self.inner.uds_path {
            let _ = std::fs::remove_file(path);
        }
        self.inner.metrics.lock().unwrap().clone()
    }
}

impl Drop for ServiceHandle {
    fn drop(&mut self) {
        // `join` drains `threads`; a handle dropped without joining
        // still asks the daemon to stop (threads detach and exit on
        // their next poll tick).
        if !self.threads.is_empty() {
            self.inner.request_stop();
        }
    }
}

enum Listener {
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl Listener {
    fn accept(&self) -> io::Result<Stream> {
        match self {
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                Ok(Stream::Unix(s))
            }
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                s.set_nodelay(true)?;
                Ok(Stream::Tcp(s))
            }
        }
    }
}

/// Serve on a Unix-domain socket at `path` (a stale socket file is
/// replaced).
pub fn serve_unix(path: &Path, cfg: ServiceConfig) -> io::Result<ServiceHandle> {
    let _ = std::fs::remove_file(path);
    let listener = UnixListener::bind(path)?;
    listener.set_nonblocking(true)?;
    serve(Listener::Unix(listener), cfg, None, Some(path.to_path_buf()))
}

/// Serve on a TCP address (`"127.0.0.1:0"` binds an ephemeral port —
/// read it back from [`ServiceHandle::addr`]).
pub fn serve_tcp(addr: &str, cfg: ServiceConfig) -> io::Result<ServiceHandle> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    let bound = listener.local_addr()?;
    serve(Listener::Tcp(listener), cfg, Some(bound), None)
}

fn serve(
    listener: Listener,
    cfg: ServiceConfig,
    addr: Option<SocketAddr>,
    uds_path: Option<PathBuf>,
) -> io::Result<ServiceHandle> {
    // `queue_cap == 0` is deliberately legal: a zero-capacity queue
    // refuses every request, which is exactly what the client-side
    // admission-exhaustion path is tested against.
    if cfg.p == 0 || cfg.batch_max == 0 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            "service: p and batch_max must both be >= 1",
        ));
    }
    if let Some((rank, _)) = cfg.fault {
        if rank >= cfg.p || cfg.p == 1 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "service: fault rank {rank} invalid for p = {} (need rank < p and p > 1)",
                    cfg.p
                ),
            ));
        }
    }
    let mut metrics = ServiceMetrics::default();
    if let Some(plan) = cfg.chaos {
        // The probe's world is this daemon's wire: whatever it healed
        // seeds the daemon-scoped counters.
        metrics.wire =
            chaos_probe(plan).map_err(|msg| io::Error::new(io::ErrorKind::InvalidInput, msg))?;
    }
    let inner = Arc::new(Inner {
        cfg,
        queue: Mutex::new(VecDeque::new()),
        cv: Condvar::new(),
        stop: AtomicBool::new(false),
        metrics: Mutex::new(metrics),
        rejects: Mutex::new(HashMap::new()),
        conns: Mutex::new(Vec::new()),
        addr,
        uds_path,
    });
    let accept = {
        let inner = inner.clone();
        thread::Builder::new()
            .name("cbcastd-accept".into())
            .spawn(move || accept_loop(&inner, listener))?
    };
    let batcher = {
        let inner = inner.clone();
        thread::Builder::new().name("cbcastd-batch".into()).spawn(move || batch_loop(&inner))?
    };
    Ok(ServiceHandle { inner, threads: vec![accept, batcher] })
}

/// The startup self-probe behind [`ServiceConfig::chaos`]: one small
/// broadcast over a two-rank chaos-socket world under the configured
/// plan, with a **zero** shrink budget — the probe passes iff the
/// protocol-v3 reliability layer heals every injected fault without
/// consuming a membership epoch and without corrupting the payload.
/// Returns the probe world's own wire-fault counters
/// ([`crate::comm::membership::ElasticReport::wire`]) so the caller can
/// seed the daemon-scoped [`ServiceMetrics::wire`].
fn chaos_probe(plan: FaultPlan) -> Result<WireFaults, String> {
    let data: Vec<i64> = (0..64).map(|i| i * 7 - 3).collect();
    let report = elastic_bcast(
        2,
        0,
        &data,
        4,
        TransportKind::ChaosSocket(plan),
        &CrashPlan::none(),
        0,
        Duration::from_secs(10),
    )
    .map_err(|e| format!("service: chaos self-probe did not heal under the plan: {e}"))?;
    if !report.changes.is_empty() {
        return Err(
            "service: chaos self-probe consumed a membership epoch (plan too hostile)"
                .to_string(),
        );
    }
    for (g, buf) in &report.buffers {
        if buf != &data {
            return Err(format!(
                "service: chaos self-probe delivered a corrupted payload at rank {g}"
            ));
        }
    }
    Ok(report.wire)
}

fn accept_loop(inner: &Arc<Inner>, listener: Listener) {
    while !inner.stopping() {
        match listener.accept() {
            Ok(stream) => {
                inner.metrics.lock().unwrap().connections += 1;
                let conn_inner = inner.clone();
                let handle = thread::Builder::new()
                    .name("cbcastd-conn".into())
                    .spawn(move || handle_conn(&conn_inner, stream));
                if let Ok(h) = handle {
                    inner.conns.lock().unwrap().push(h);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(2));
            }
            Err(_) => thread::sleep(Duration::from_millis(2)),
        }
    }
}

/// What one idle-tolerant read step produced.
enum Incoming {
    Frame(u8, Vec<u8>),
    /// No frame started before the poll deadline — not an error.
    Idle,
    /// Clean EOF between frames.
    Closed,
}

/// Read one frame, distinguishing idleness from a slow-loris stall:
/// the *first* byte is awaited under a short `poll` deadline (a miss is
/// [`Incoming::Idle`] — loop and re-check shutdown); once a frame has
/// started, the rest must arrive within `frame_timeout` or the read
/// errors out and the caller drops the connection.
fn read_frame_idle(
    s: &mut Stream,
    poll: Duration,
    frame_timeout: Duration,
) -> io::Result<Incoming> {
    let _ = s.set_read_timeout(Some(poll));
    let mut first = [0u8; 1];
    loop {
        match s.read(&mut first) {
            Ok(0) => return Ok(Incoming::Closed),
            Ok(_) => break,
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                return Ok(Incoming::Idle)
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let _ = s.set_read_timeout(Some(frame_timeout));
    let mut rest = [0u8; 3];
    if !fill(s, &mut rest)? {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            "service: connection closed inside a frame header",
        ));
    }
    let len = u32::from_le_bytes([first[0], rest[0], rest[1], rest[2]]) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("service: frame length {len} out of range"),
        ));
    }
    let mut buf = vec![0u8; len];
    if !fill(s, &mut buf)? {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            "service: connection closed inside a frame body",
        ));
    }
    let kind = buf[0];
    let body = buf.split_off(1);
    Ok(Incoming::Frame(kind, body))
}

fn drop_conn(inner: &Inner) {
    inner.metrics.lock().unwrap().dropped += 1;
}

fn send_frame(reply: &Arc<Mutex<Stream>>, frame: &[u8]) {
    // A vanished client just loses its reply; the batch is unaffected.
    let _ = reply.lock().unwrap().write_all(frame);
}

fn handle_conn(inner: &Arc<Inner>, mut stream: Stream) {
    // Handshake under the full frame deadline: a client that connects
    // and stalls is a slow-loris from byte one.
    let _ = stream.set_read_timeout(Some(inner.cfg.client_timeout));
    let tenant: Arc<str> = match read_raw_frame(&mut stream) {
        Ok(Some((FT_CHELLO, body))) => match parse_chello(&body) {
            Ok(t) => Arc::from(t.as_str()),
            Err(_) => return drop_conn(inner),
        },
        _ => return drop_conn(inner),
    };
    if stream.write_all(&shello_frame(inner.cfg.p)).is_err() {
        return drop_conn(inner);
    }
    let reply = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return drop_conn(inner),
    };

    // Idle polls stay short so shutdown is responsive regardless of
    // how generous the slow-loris cutoff is.
    let poll = inner.cfg.client_timeout.min(Duration::from_millis(100));
    loop {
        if inner.stopping() {
            return;
        }
        let (kind, body) = match read_frame_idle(&mut stream, poll, inner.cfg.client_timeout) {
            Ok(Incoming::Frame(kind, body)) => (kind, body),
            Ok(Incoming::Idle) => continue,
            Ok(Incoming::Closed) => return,
            Err(_) => return drop_conn(inner),
        };
        match kind {
            FT_REQ => {
                let (req_id, spec) = match parse_req(&body) {
                    Ok(x) => x,
                    Err(_) => return drop_conn(inner),
                };
                admit(inner, &tenant, req_id, spec, &reply);
            }
            FT_STATS => {
                let text = render_stats(inner);
                send_frame(&reply, &stats_res_frame(&text));
            }
            FT_BYE => return,
            FT_SHUTDOWN => {
                inner.request_stop();
                return;
            }
            _ => return drop_conn(inner),
        }
    }
}

/// Admission control: an oversized op fails outright, a full queue
/// refuses with the retry hint, everything else enqueues for the
/// batcher.
fn admit(inner: &Inner, tenant: &Arc<str>, req_id: u64, spec: MixOp, reply: &Arc<Mutex<Stream>>) {
    if spec.m > MAX_OP_M {
        inner.metrics.lock().unwrap().failed += 1;
        let msg = format!("bad request: payload scale {} exceeds daemon cap {MAX_OP_M}", spec.m);
        send_frame(reply, &res_err_frame(req_id, &msg));
        return;
    }
    let mut q = inner.queue.lock().unwrap();
    if q.len() >= inner.cfg.queue_cap {
        drop(q);
        *inner.rejects.lock().unwrap().entry(tenant.to_string()).or_insert(0) += 1;
        inner.metrics.lock().unwrap().rejected += 1;
        let hint = inner.cfg.retry_after.as_millis().min(u32::MAX as u128) as u32;
        send_frame(reply, &res_reject_frame(req_id, hint.max(1)));
    } else {
        q.push_back(Job { tenant: tenant.clone(), spec, req_id, reply: reply.clone() });
        inner.cv.notify_all();
        drop(q);
        inner.metrics.lock().unwrap().admitted += 1;
    }
}

fn batch_loop(inner: &Arc<Inner>) {
    // The batcher owns the communicator — schedule tables are computed
    // once and reused across every batch. Under the recovery plane the
    // communicator is *rebuildable*: when a rank dies the membership
    // shrinks and a fresh (p − 1)-rank communicator takes over (cheap
    // by the paper's construction — every schedule row is recomputed
    // locally in O(log p), no state is redistributed).
    let mut membership = Membership::new(inner.cfg.p);
    let mut comm = CommBuilder::new(inner.cfg.p).build();
    let mut batch_no = 0usize;
    loop {
        let mut q = inner.queue.lock().unwrap();
        while q.is_empty() && !inner.stopping() {
            let (guard, _) = inner.cv.wait_timeout(q, Duration::from_millis(50)).unwrap();
            q = guard;
        }
        if q.is_empty() {
            return; // stop requested with nothing left to drain
        }
        drop(q);
        // Gather window: let concurrently-arriving requests join this
        // batch instead of each riding alone.
        thread::sleep(inner.cfg.gather);
        let jobs: Vec<Job> = {
            let mut q = inner.queue.lock().unwrap();
            let n = q.len().min(inner.cfg.batch_max);
            q.drain(..n).collect()
        };
        // The deterministic fault: the configured rank dies while this
        // batch is in flight. The batch still runs on the current
        // world; `run_batch` then replays only the ops the death
        // disrupted on the shrunken, rebuilt communicator.
        let fault = match inner.cfg.fault {
            Some((victim, during))
                if batch_no == during && membership.dense(victim).is_some() =>
            {
                Some(victim)
            }
            _ => None,
        };
        run_batch(inner, &mut membership, &mut comm, jobs, fault);
        batch_no += 1;
    }
}

/// Re-express a client's op spec (always phrased in the **original**
/// epoch-0 frame the client was told at handshake) in the current
/// membership's dense frame. Identity at epoch 0. After a shrink:
/// windows drop their dead ranks and slide down ([`Membership::
/// remap_window`]); a dead root is replaced by the window's lowest
/// surviving rank; a window that lost *every* rank is an error — the
/// op has no world left to run on.
fn remap_spec(spec: &MixOp, ms: &Membership) -> Result<MixOp, String> {
    if ms.epoch() == 0 {
        return Ok(spec.clone());
    }
    let mut out = spec.clone();
    match spec.window {
        None => {
            let root_g = ms.elect_root(spec.root);
            out.root = ms.dense(root_g).expect("elected root is a member");
        }
        Some((base, len)) => {
            let Some((base_d, len_d)) = ms.remap_window(base, len) else {
                return Err(format!(
                    "window ({base}, {len}) lost every rank to membership \
                     changes (epoch {})",
                    ms.epoch()
                ));
            };
            out.window = Some((base_d, len_d));
            out.root = match ms.dense(base + spec.root) {
                Some(d) => d - base_d,
                // The window-local root died: its lowest survivor —
                // dense index `base_d`, window-local 0 — takes over.
                None => 0,
            };
        }
    }
    Ok(out)
}

/// Remap and submit a set of jobs into `traffic`. A job that fails the
/// remap or the submission gets its error reply immediately; the count
/// of those is returned alongside the admitted `(job, pending)` pairs
/// (in submission order — 1:1 with the run's `BatchReport::ops`).
fn submit_jobs(
    traffic: &mut TrafficEngine<'_>,
    membership: &Membership,
    jobs: Vec<Job>,
) -> (Vec<(Job, MixPending)>, usize) {
    let mut failed = 0usize;
    let mut admitted: Vec<(Job, MixPending)> = Vec::new();
    for job in jobs {
        let spec = match remap_spec(&job.spec, membership) {
            Ok(s) => s,
            Err(msg) => {
                failed += 1;
                send_frame(&job.reply, &res_err_frame(job.req_id, &format!("bad request: {msg}")));
                continue;
            }
        };
        traffic.for_tenant(&job.tenant);
        match submit_mix_op(traffic, &spec) {
            Ok(pending) => admitted.push((job, pending)),
            Err(e) => {
                failed += 1;
                send_frame(&job.reply, &res_err_frame(job.req_id, &format!("{e}")));
            }
        }
    }
    (admitted, failed)
}

/// Take one finished op's outcome and reply to its client.
fn settle(job: &Job, pending: MixPending, completed: &mut usize, failed: &mut usize) {
    match summarize(&pending.take()) {
        Ok(summary) => {
            *completed += 1;
            send_frame(&job.reply, &res_ok_frame(job.req_id, &summary));
        }
        Err(msg) => {
            *failed += 1;
            send_frame(&job.reply, &res_err_frame(job.req_id, &msg));
        }
    }
}

/// Strike one op's phase-1 usage out of the batch's tenant rows: the op
/// is about to be replayed on the rebuilt world, and the replay run
/// bills it again — without the discharge a restarted op would
/// double-count in `ops`/`ok`/`messages`/`bytes`.
fn discharge_op(tenants: &mut [TenantUsage], op: &OpReport) {
    let Some(tenant) = &op.tenant else { return };
    if let Some(row) = tenants.iter_mut().find(|u| u.tenant == **tenant) {
        row.ops -= 1;
        row.ok -= usize::from(op.ok);
        row.messages -= op.messages;
        row.bytes -= op.bytes;
    }
}

fn run_batch(
    inner: &Inner,
    membership: &mut Membership,
    comm: &mut Communicator,
    jobs: Vec<Job>,
    fault: Option<usize>,
) {
    // Phase 1: the whole batch runs on the current world — the fault
    // (if any) is discovered *after* the run, as it would be on a real
    // wire, and decides per-op what can be kept.
    let mut traffic = comm.traffic();
    if let Some(t) = inner.cfg.threads {
        traffic = traffic.threads(t);
    }
    let (admitted, submit_failed) = submit_jobs(&mut traffic, membership, jobs);
    let mut report = match traffic.run() {
        Ok(r) => r,
        Err(e) => {
            let msg = format!("batch execution failed: {e}");
            let n = admitted.len();
            for (job, _) in &admitted {
                send_frame(&job.reply, &res_err_frame(job.req_id, &msg));
            }
            let mut m = inner.metrics.lock().unwrap();
            m.failed += submit_failed + n;
            return;
        }
    };
    // Charge the admission refusals accumulated since the last batch.
    for (tenant, n) in inner.rejects.lock().unwrap().drain() {
        report.note_rejected(&tenant, n);
    }

    let mut completed = 0usize;
    let mut failed = submit_failed;
    let mut replay: Vec<Job> = Vec::new();
    if let Some(victim) = fault {
        // The victim died while the batch was in flight. Only the ops
        // the death disrupted — [`BatchReport::restart_set`]: failed
        // ops, plus ops whose window contains the victim — are
        // replayed. A completed op over a disjoint window keeps its
        // result, replies from phase 1, and is billed exactly once.
        let vd = membership.dense(victim).expect("fault victim is a member");
        debug_assert_eq!(report.ops.len(), admitted.len());
        let mut is_restart = vec![false; admitted.len()];
        for i in report.restart_set(&[vd]) {
            is_restart[i] = true;
        }
        for (i, (job, pending)) in admitted.into_iter().enumerate() {
            if is_restart[i] {
                discharge_op(&mut report.tenants, &report.ops[i]);
                drop(pending); // phase-1 result untrusted — discarded
                replay.push(job);
            } else {
                settle(&job, pending, &mut completed, &mut failed);
            }
        }
        // Shrink, rebuild, count the recovery. Schedule rows on the
        // (p − 1)-rank world are recomputed locally in O(log p).
        let (next, _change) = membership.shrink(&[victim]);
        *membership = next;
        *comm = CommBuilder::new(membership.p()).build();
        {
            let mut m = inner.metrics.lock().unwrap();
            m.recoveries += 1;
            m.epoch = membership.epoch();
        }
        // Bill each disruption to the tenant whose op is re-admitted
        // onto the rebuilt communicator (also billed when the replay
        // remap then fails — the disruption still happened to them).
        for job in &replay {
            if let Some(row) = report.tenants.iter_mut().find(|u| u.tenant == *job.tenant) {
                row.restarted += 1;
            } else {
                report.tenants.push(TenantUsage {
                    tenant: job.tenant.to_string(),
                    restarted: 1,
                    ..TenantUsage::default()
                });
            }
        }
    } else {
        for (job, pending) in admitted {
            settle(&job, pending, &mut completed, &mut failed);
        }
    }

    // Phase 2: replay only the disrupted ops on the rebuilt world.
    if !replay.is_empty() {
        let mut traffic = comm.traffic();
        if let Some(t) = inner.cfg.threads {
            traffic = traffic.threads(t);
        }
        let (readmitted, replay_failed) = submit_jobs(&mut traffic, membership, replay);
        failed += replay_failed;
        match traffic.run() {
            Ok(rep2) => {
                for (job, pending) in readmitted {
                    settle(&job, pending, &mut completed, &mut failed);
                }
                fold_usage(&mut report.tenants, &rep2.tenants);
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e}");
                failed += readmitted.len();
                for (job, _) in &readmitted {
                    send_frame(&job.reply, &res_err_frame(job.req_id, &msg));
                }
            }
        }
    }

    let mut m = inner.metrics.lock().unwrap();
    m.batches += 1;
    m.completed += completed;
    m.failed += failed;
    fold_usage(&mut m.tenants, &report.tenants);
}

/// Fold one batch's tenant rows into the cumulative metrics rows.
fn fold_usage(total: &mut Vec<TenantUsage>, batch: &[TenantUsage]) {
    for row in batch {
        let idx = match total.iter().position(|u| u.tenant == row.tenant) {
            Some(i) => i,
            None => {
                total.push(TenantUsage { tenant: row.tenant.clone(), ..TenantUsage::default() });
                total.len() - 1
            }
        };
        let t = &mut total[idx];
        t.ops += row.ops;
        t.ok += row.ok;
        t.messages += row.messages;
        t.bytes += row.bytes;
        t.rejected += row.rejected;
        t.restarted += row.restarted;
    }
}

fn render_stats(inner: &Inner) -> String {
    let depth = inner.queue.lock().unwrap().len();
    let m = inner.metrics.lock().unwrap();
    let mut out = format!(
        "p={} queue_depth={} connections={} admitted={} rejected={} completed={} failed={} \
         batches={} dropped={} recoveries={} epoch={}\n",
        inner.cfg.p,
        depth,
        m.connections,
        m.admitted,
        m.rejected,
        m.completed,
        m.failed,
        m.batches,
        m.dropped,
        m.recoveries,
        m.epoch,
    );
    out.push_str(&format!("wire: {}\n", m.wire));
    for t in &m.tenants {
        out.push_str(&format!(
            "tenant={} ops={} ok={} messages={} bytes={} rejected={} restarted={}\n",
            t.tenant, t.ops, t.ok, t.messages, t.bytes, t.rejected, t.restarted
        ));
    }
    out
}
