//! The client side of the collective service: connect, submit op
//! specs, collect digests — never payload buffers.

use std::io::{self, Write};
use std::net::TcpStream;
use std::os::unix::net::UnixStream;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::comm::socket::{read_raw_frame, Stream};
use crate::comm::transport::configured_timeout;
use crate::testkit::MixOp;

use super::wire::{
    bye_frame, chello_frame, parse_res_err, parse_res_ok, parse_res_reject, parse_shello,
    parse_stats_res, req_frame, shutdown_frame, stats_frame, FT_RES_ERR, FT_RES_OK,
    FT_RES_REJECT, FT_SHELLO, FT_STATS_RES,
};
use super::ServiceReply;

fn proto(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Default submission budget for [`ServiceClient::call_admitted`]:
/// with the doubling backoff this tolerates minutes of daemon
/// saturation before giving up, while still guaranteeing termination
/// against a zero-capacity daemon.
pub const DEFAULT_ADMIT_ATTEMPTS: usize = 16;

/// One connection to a running daemon, identified by a tenant label.
///
/// Replies to this connection's requests arrive in submission order
/// (the daemon admits a connection's frames FIFO and replies per batch
/// in admission order), so a pipelining client can match them by
/// `req_id` without reordering; [`ServiceClient::call`] is the simple
/// one-outstanding-request wrapper.
pub struct ServiceClient {
    stream: Stream,
    p: usize,
    /// Seed of this tenant's admission-backoff jitter, derived from the
    /// tenant label at handshake ([`tenant_seed`]) — deterministic per
    /// tenant, distinct across tenants, so saturated clients desynchronize
    /// instead of stampeding the daemon in lockstep.
    jitter_seed: u64,
}

/// FNV-1a-64 of a tenant label: the deterministic jitter seed. Two
/// tenants hammering a saturated daemon retry on *different* (but each
/// individually replayable) sleep schedules.
pub(crate) fn tenant_seed(tenant: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in tenant.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// SplitMix64 finalizer — the same stateless per-index hash the chaos
/// plane uses ([`crate::comm::chaos`]): one u64 in, one well-mixed u64
/// out, no RNG state to thread around.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Per-refusal backoff cap: no single sleep exceeds this, however far
/// the doubling has climbed.
const BACKOFF_CAP: Duration = Duration::from_millis(500);

/// The sleep after the `attempt`-th admission refusal (0-indexed):
/// the daemon's `retry_after` hint doubled per refusal, capped at
/// [`BACKOFF_CAP`], then jittered into `[50%, 100%]` of that base by a
/// stateless hash of `(seed, attempt)`. Deterministic — same tenant,
/// same refusal index, same sleep — which is what lets the test suite
/// pin two tenants to *distinct* schedules without any timing games.
fn jittered_backoff(hint_ms: u32, attempt: usize, seed: u64) -> Duration {
    let hint = Duration::from_millis(hint_ms.max(1) as u64);
    let base = hint.saturating_mul(1u32 << attempt.min(8) as u32).min(BACKOFF_CAP);
    let base_us = base.as_micros() as u64;
    let h = mix64(seed ^ (attempt as u64).wrapping_mul(0xA076_1D64_78BD_642F));
    let half = base_us / 2;
    Duration::from_micros(half + h % (half + 1))
}

/// The full sleep schedule a client with `seed` would follow through
/// `attempts` submissions (there is one sleep *between* consecutive
/// submissions, so the schedule has `attempts − 1` entries). Pure —
/// exists so tests can assert schedule properties without sleeping.
pub(crate) fn backoff_schedule(hint_ms: u32, attempts: usize, seed: u64) -> Vec<Duration> {
    (0..attempts.saturating_sub(1)).map(|a| jittered_backoff(hint_ms, a, seed)).collect()
}

impl ServiceClient {
    /// Connect over a Unix-domain socket.
    pub fn connect_unix(path: &Path, tenant: &str) -> io::Result<ServiceClient> {
        Self::handshake(Stream::Unix(UnixStream::connect(path)?), tenant)
    }

    /// [`ServiceClient::connect_unix`], retrying while the daemon is
    /// still binding (races a just-spawned daemon politely).
    pub fn connect_unix_retry(
        path: &Path,
        tenant: &str,
        patience: Duration,
    ) -> io::Result<ServiceClient> {
        let deadline = Instant::now() + patience;
        loop {
            match UnixStream::connect(path) {
                Ok(s) => return Self::handshake(Stream::Unix(s), tenant),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        }
    }

    /// Connect over TCP.
    pub fn connect_tcp(addr: &str, tenant: &str) -> io::Result<ServiceClient> {
        let s = TcpStream::connect(addr)?;
        s.set_nodelay(true)?;
        Self::handshake(Stream::Tcp(s), tenant)
    }

    fn handshake(mut stream: Stream, tenant: &str) -> io::Result<ServiceClient> {
        // Replies can wait on whole batches; reuse the transport-plane
        // deadline (`CBCAST_TRANSPORT_TIMEOUT_MS`, default 30 s).
        stream.set_read_timeout(Some(configured_timeout()))?;
        stream.write_all(&chello_frame(tenant))?;
        match read_raw_frame(&mut stream)? {
            Some((FT_SHELLO, body)) => {
                let p = parse_shello(&body)?;
                Ok(ServiceClient { stream, p, jitter_seed: tenant_seed(tenant) })
            }
            Some((kind, _)) => Err(proto(format!(
                "service handshake: expected server hello, got frame type {kind:#x}"
            ))),
            None => Err(proto("service handshake: daemon closed the connection")),
        }
    }

    /// Machine size of the daemon's communicator (from the handshake).
    pub fn p(&self) -> usize {
        self.p
    }

    /// Ship one op spec under `req_id` without waiting for the reply.
    pub fn submit(&mut self, req_id: u64, op: &MixOp) -> io::Result<()> {
        self.stream.write_all(&req_frame(req_id, op))
    }

    /// Read the next reply frame: `(req_id, reply)`.
    pub fn recv_reply(&mut self) -> io::Result<(u64, ServiceReply)> {
        match read_raw_frame(&mut self.stream)? {
            Some((FT_RES_OK, body)) => {
                let (id, summary) = parse_res_ok(&body)?;
                Ok((id, ServiceReply::Ok(summary)))
            }
            Some((FT_RES_ERR, body)) => {
                let (id, msg) = parse_res_err(&body)?;
                Ok((id, ServiceReply::Err(msg)))
            }
            Some((FT_RES_REJECT, body)) => {
                let (id, retry_after_ms) = parse_res_reject(&body)?;
                Ok((id, ServiceReply::Rejected { retry_after_ms }))
            }
            Some((kind, _)) => {
                Err(proto(format!("service: unexpected reply frame type {kind:#x}")))
            }
            None => Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "service: daemon closed the connection",
            )),
        }
    }

    /// Submit one op and wait for its reply (one outstanding request).
    pub fn call(&mut self, req_id: u64, op: &MixOp) -> io::Result<ServiceReply> {
        self.submit(req_id, op)?;
        let (id, reply) = self.recv_reply()?;
        if id != req_id {
            return Err(proto(format!("service: reply for request {id}, expected {req_id}")));
        }
        Ok(reply)
    }

    /// [`ServiceClient::call_admitted_budget`] with the default budget
    /// of [`DEFAULT_ADMIT_ATTEMPTS`] submissions.
    pub fn call_admitted(&mut self, req_id: u64, op: &MixOp) -> io::Result<ServiceReply> {
        self.call_admitted_budget(req_id, op, DEFAULT_ADMIT_ATTEMPTS)
    }

    /// [`ServiceClient::call`], resubmitting after each admission
    /// refusal — returns the first non-rejected reply.
    ///
    /// The retry is **bounded**: at most `attempts` submissions, sleeping
    /// the daemon's `retry_after` hint doubled per refusal (capped at
    /// 500 ms per sleep) and **jittered** into 50–100% of that base by a
    /// deterministic per-tenant hash ([`jittered_backoff`]) — so tenants
    /// refused together do not resubmit together. A daemon that refuses
    /// every attempt — e.g. one configured with a zero-capacity queue,
    /// or permanently saturated — yields a typed
    /// [`io::ErrorKind::TimedOut`] "admission exhausted" error instead
    /// of the pre-fix unbounded spin.
    pub fn call_admitted_budget(
        &mut self,
        req_id: u64,
        op: &MixOp,
        attempts: usize,
    ) -> io::Result<ServiceReply> {
        for attempt in 0..attempts {
            match self.call(req_id, op)? {
                ServiceReply::Rejected { retry_after_ms } => {
                    if attempt + 1 == attempts {
                        break; // budget spent: no point sleeping again
                    }
                    std::thread::sleep(jittered_backoff(
                        retry_after_ms,
                        attempt,
                        self.jitter_seed,
                    ));
                }
                reply => return Ok(reply),
            }
        }
        Err(io::Error::new(
            io::ErrorKind::TimedOut,
            format!(
                "service: admission exhausted after {attempts} attempts \
                 (request {req_id} kept being refused; daemon saturated?)"
            ),
        ))
    }

    /// Fetch the daemon's counters as one text blob.
    pub fn stats(&mut self) -> io::Result<String> {
        self.stream.write_all(&stats_frame())?;
        match read_raw_frame(&mut self.stream)? {
            Some((FT_STATS_RES, body)) => parse_stats_res(&body),
            Some((kind, _)) => {
                Err(proto(format!("service: expected stats, got frame type {kind:#x}")))
            }
            None => Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "service: daemon closed the connection",
            )),
        }
    }

    /// Clean goodbye.
    pub fn bye(mut self) -> io::Result<()> {
        self.stream.write_all(&bye_frame())
    }

    /// Administrative daemon shutdown (CI teardown).
    pub fn shutdown_daemon(mut self) -> io::Result<()> {
        self.stream.write_all(&shutdown_frame())
    }
}
