//! The client↔daemon request protocol, layered on the wire plane's
//! framing ([`crate::comm::socket`]): same `[len | type | body]` frames,
//! a disjoint frame-type range (`0x10..`), and a `MixOp` codec so a
//! client ships an operation *specification* — never payload buffers.
//! Payloads are derived deterministically on both sides from the op's
//! `data_seed` (the [`crate::testkit::MixOp`] convention), which is what
//! makes the differential check cheap: the daemon returns a digest and
//! the client can recompute the expected digest from a solo run.
//!
//! Service frames deliberately stay on the CRC-less `seal`/raw-read
//! path: the protocol-v3 CRC/seq/ack reliability machinery belongs to
//! the rank plane's DATA traffic (where a corrupted frame must heal by
//! retransmission mid-collective), while a service connection is plain
//! request/response — a mangled frame here is a protocol error that
//! drops the connection, exactly as before. The shared `VERSION` bump
//! to 3 is transparent to this protocol: both sides compare the same
//! constant in their hellos.

use std::io;

use crate::comm::socket::{put_str, put_u16, put_u32, put_u64, seal, Body, MAGIC, VERSION};
use crate::comm::{Algo, Kind};
use crate::testkit::{MixOp, MixOutcome};

// Service frame types — disjoint from the transport's `1..=4` range so
// a stray transport frame on a service connection is an instant
// protocol error, not a misparse.
/// Client hello: `magic, version, tenant`.
pub(crate) const FT_CHELLO: u8 = 0x10;
/// Server hello: `magic, version, p`.
pub(crate) const FT_SHELLO: u8 = 0x11;
/// Collective request: `req_id, MixOp`.
pub(crate) const FT_REQ: u8 = 0x12;
/// Completed op: `req_id, OpSummary`.
pub(crate) const FT_RES_OK: u8 = 0x13;
/// Failed op (or malformed request): `req_id, message`.
pub(crate) const FT_RES_ERR: u8 = 0x14;
/// Admission refusal: `req_id, retry_after_ms`.
pub(crate) const FT_RES_REJECT: u8 = 0x15;
/// Stats request (empty body).
pub(crate) const FT_STATS: u8 = 0x16;
/// Stats response: one text blob.
pub(crate) const FT_STATS_RES: u8 = 0x17;
/// Clean client goodbye (empty body).
pub(crate) const FT_BYE: u8 = 0x18;
/// Administrative daemon shutdown (empty body).
pub(crate) const FT_SHUTDOWN: u8 = 0x19;

fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

// --- enum codecs ------------------------------------------------------

pub(crate) fn kind_code(k: Kind) -> u8 {
    match k {
        Kind::Bcast => 0,
        Kind::Reduce => 1,
        Kind::Allgatherv => 2,
        Kind::ReduceScatter => 3,
        Kind::Allreduce => 4,
    }
}

pub(crate) fn kind_from(code: u8) -> io::Result<Kind> {
    Ok(match code {
        0 => Kind::Bcast,
        1 => Kind::Reduce,
        2 => Kind::Allgatherv,
        3 => Kind::ReduceScatter,
        4 => Kind::Allreduce,
        c => return Err(bad(format!("service: unknown collective kind code {c}"))),
    })
}

pub(crate) fn algo_code(a: Algo) -> u8 {
    match a {
        Algo::Auto => 0,
        Algo::Circulant => 1,
        Algo::Binomial => 2,
        Algo::VanDeGeijn => 3,
        Algo::Ring => 4,
        Algo::RecursiveHalving => 5,
        Algo::OptTree => 6,
    }
}

pub(crate) fn algo_from(code: u8) -> io::Result<Algo> {
    Ok(match code {
        0 => Algo::Auto,
        1 => Algo::Circulant,
        2 => Algo::Binomial,
        3 => Algo::VanDeGeijn,
        4 => Algo::Ring,
        5 => Algo::RecursiveHalving,
        6 => Algo::OptTree,
        c => return Err(bad(format!("service: unknown algorithm code {c}"))),
    })
}

// --- hello frames -----------------------------------------------------

pub(crate) fn chello_frame(tenant: &str) -> Vec<u8> {
    let mut b = Vec::with_capacity(16 + tenant.len());
    put_u32(&mut b, MAGIC);
    put_u16(&mut b, VERSION);
    put_str(&mut b, tenant);
    seal(FT_CHELLO, &b)
}

pub(crate) fn parse_chello(body: &[u8]) -> io::Result<String> {
    let mut b = Body::new(body);
    if b.u32()? != MAGIC {
        return Err(bad("service handshake: bad magic"));
    }
    let v = b.u16()?;
    if v != VERSION {
        return Err(bad(format!("service handshake: version {v}, daemon speaks {VERSION}")));
    }
    let tenant = b.str()?;
    if tenant.is_empty() || tenant.len() > 64 {
        return Err(bad("service handshake: tenant label must be 1..=64 bytes"));
    }
    Ok(tenant)
}

pub(crate) fn shello_frame(p: usize) -> Vec<u8> {
    let mut b = Vec::with_capacity(10);
    put_u32(&mut b, MAGIC);
    put_u16(&mut b, VERSION);
    put_u32(&mut b, p as u32);
    seal(FT_SHELLO, &b)
}

pub(crate) fn parse_shello(body: &[u8]) -> io::Result<usize> {
    let mut b = Body::new(body);
    if b.u32()? != MAGIC {
        return Err(bad("service handshake: bad magic"));
    }
    let v = b.u16()?;
    if v != VERSION {
        return Err(bad(format!("service handshake: version {v}, client speaks {VERSION}")));
    }
    Ok(b.u32()? as usize)
}

// --- request frame ----------------------------------------------------

/// Serialize a request: `req_id` then the op spec (kind, window, root,
/// m, blocks, algo, data_seed). No payload bytes ever cross — both
/// sides regenerate them from `data_seed`.
pub(crate) fn req_frame(req_id: u64, op: &MixOp) -> Vec<u8> {
    let mut b = Vec::with_capacity(48);
    put_u64(&mut b, req_id);
    b.push(kind_code(op.kind));
    match op.window {
        Some((base, len)) => {
            b.push(1);
            put_u32(&mut b, base as u32);
            put_u32(&mut b, len as u32);
        }
        None => b.push(0),
    }
    put_u32(&mut b, op.root as u32);
    put_u32(&mut b, op.m as u32);
    match op.blocks {
        Some(n) => {
            b.push(1);
            put_u32(&mut b, n as u32);
        }
        None => b.push(0),
    }
    b.push(algo_code(op.algo));
    put_u64(&mut b, op.data_seed);
    seal(FT_REQ, &b)
}

pub(crate) fn parse_req(body: &[u8]) -> io::Result<(u64, MixOp)> {
    let mut b = Body::new(body);
    let req_id = b.u64()?;
    let kind = kind_from(b.u8()?)?;
    let window = match b.u8()? {
        0 => None,
        1 => Some((b.u32()? as usize, b.u32()? as usize)),
        c => return Err(bad(format!("service request: bad window tag {c}"))),
    };
    let root = b.u32()? as usize;
    let m = b.u32()? as usize;
    let blocks = match b.u8()? {
        0 => None,
        1 => Some(b.u32()? as usize),
        c => return Err(bad(format!("service request: bad blocks tag {c}"))),
    };
    let algo = algo_from(b.u8()?)?;
    let data_seed = b.u64()?;
    Ok((req_id, MixOp { kind, window, root, m, blocks, algo, data_seed }))
}

// --- response frames --------------------------------------------------

/// What the daemon returns for a completed op: a content digest over
/// the rank-major result buffers plus the full statistics line — enough
/// for a client to assert bit-identity against a solo
/// [`crate::testkit::run_mix_blocking`] run without shipping buffers.
#[derive(Debug, Clone, PartialEq)]
pub struct OpSummary {
    /// FNV-1a digest of the rank-major result buffers ([`mix_digest`]).
    pub digest: u64,
    pub complete: bool,
    /// The resolved algorithm (never `Auto`).
    pub algo: Algo,
    pub rounds: usize,
    pub active_rounds: usize,
    pub messages: usize,
    pub bytes: usize,
    pub max_rank_bytes: usize,
    pub time: f64,
}

/// One reply to a submitted request.
#[derive(Debug, Clone, PartialEq)]
pub enum ServiceReply {
    /// The op ran; compare the summary against a solo run.
    Ok(OpSummary),
    /// The op was admitted but failed (or was malformed) — the
    /// `CommError` display string, same as [`MixOutcome::Failed`].
    Err(String),
    /// Admission control refused the op (queue saturated); resubmit
    /// after the hinted backoff.
    Rejected { retry_after_ms: u32 },
}

/// FNV-1a over the rank-major buffers, mixing each rank's length so
/// `[[1],[  ]]` and `[[ ],[1]]` digest differently.
pub fn mix_digest(buffers: &[Vec<i64>]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut eat = |bytes: &[u8]| {
        for &byte in bytes {
            h ^= byte as u64;
            h = h.wrapping_mul(PRIME);
        }
    };
    for row in buffers {
        eat(&(row.len() as u64).to_le_bytes());
        for v in row {
            eat(&v.to_le_bytes());
        }
    }
    h
}

/// Summarize a mix outcome the way the daemon reports it: `Ok` carries
/// the digest + stats, `Err` the failure string. Clients run this on a
/// solo [`crate::testkit::run_mix_blocking`] result to get the exact
/// value the daemon's reply must equal.
pub fn summarize(outcome: &MixOutcome) -> Result<OpSummary, String> {
    match outcome {
        MixOutcome::Done {
            buffers,
            complete,
            algo,
            rounds,
            active_rounds,
            messages,
            bytes,
            max_rank_bytes,
            time,
        } => Ok(OpSummary {
            digest: mix_digest(buffers),
            complete: *complete,
            algo: *algo,
            rounds: *rounds,
            active_rounds: *active_rounds,
            messages: *messages,
            bytes: *bytes,
            max_rank_bytes: *max_rank_bytes,
            time: *time,
        }),
        MixOutcome::Failed(msg) => Err(msg.clone()),
    }
}

pub(crate) fn res_ok_frame(req_id: u64, s: &OpSummary) -> Vec<u8> {
    let mut b = Vec::with_capacity(64);
    put_u64(&mut b, req_id);
    put_u64(&mut b, s.digest);
    b.push(s.complete as u8);
    b.push(algo_code(s.algo));
    put_u32(&mut b, s.rounds as u32);
    put_u32(&mut b, s.active_rounds as u32);
    put_u64(&mut b, s.messages as u64);
    put_u64(&mut b, s.bytes as u64);
    put_u64(&mut b, s.max_rank_bytes as u64);
    put_u64(&mut b, s.time.to_bits());
    seal(FT_RES_OK, &b)
}

pub(crate) fn parse_res_ok(body: &[u8]) -> io::Result<(u64, OpSummary)> {
    let mut b = Body::new(body);
    let req_id = b.u64()?;
    let digest = b.u64()?;
    let complete = b.u8()? != 0;
    let algo = algo_from(b.u8()?)?;
    let rounds = b.u32()? as usize;
    let active_rounds = b.u32()? as usize;
    let messages = b.u64()? as usize;
    let bytes = b.u64()? as usize;
    let max_rank_bytes = b.u64()? as usize;
    let time = f64::from_bits(b.u64()?);
    Ok((
        req_id,
        OpSummary {
            digest,
            complete,
            algo,
            rounds,
            active_rounds,
            messages,
            bytes,
            max_rank_bytes,
            time,
        },
    ))
}

pub(crate) fn res_err_frame(req_id: u64, msg: &str) -> Vec<u8> {
    let mut b = Vec::with_capacity(12 + msg.len());
    put_u64(&mut b, req_id);
    put_str(&mut b, msg);
    seal(FT_RES_ERR, &b)
}

pub(crate) fn parse_res_err(body: &[u8]) -> io::Result<(u64, String)> {
    let mut b = Body::new(body);
    Ok((b.u64()?, b.str()?))
}

pub(crate) fn res_reject_frame(req_id: u64, retry_after_ms: u32) -> Vec<u8> {
    let mut b = Vec::with_capacity(12);
    put_u64(&mut b, req_id);
    put_u32(&mut b, retry_after_ms);
    seal(FT_RES_REJECT, &b)
}

pub(crate) fn parse_res_reject(body: &[u8]) -> io::Result<(u64, u32)> {
    let mut b = Body::new(body);
    Ok((b.u64()?, b.u32()?))
}

pub(crate) fn stats_frame() -> Vec<u8> {
    seal(FT_STATS, &[])
}

pub(crate) fn stats_res_frame(text: &str) -> Vec<u8> {
    let mut b = Vec::with_capacity(4 + text.len());
    put_str(&mut b, text);
    seal(FT_STATS_RES, &b)
}

pub(crate) fn parse_stats_res(body: &[u8]) -> io::Result<String> {
    Body::new(body).str()
}

pub(crate) fn bye_frame() -> Vec<u8> {
    seal(FT_BYE, &[])
}

pub(crate) fn shutdown_frame() -> Vec<u8> {
    seal(FT_SHUTDOWN, &[])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn req_frames_roundtrip_every_field_shape() {
        let ops = [
            MixOp {
                kind: Kind::Bcast,
                window: None,
                root: 3,
                m: 120,
                blocks: Some(5),
                algo: Algo::Circulant,
                data_seed: 0xDEAD_BEEF_CAFE_F00D,
            },
            MixOp {
                kind: Kind::Allreduce,
                window: Some((4, 9)),
                root: 0,
                m: 0,
                blocks: None,
                algo: Algo::Auto,
                data_seed: 1,
            },
            MixOp {
                kind: Kind::ReduceScatter,
                window: Some((0, 1)),
                root: 0,
                m: 48,
                blocks: Some(1),
                algo: Algo::RecursiveHalving,
                data_seed: u64::MAX,
            },
        ];
        for (i, op) in ops.iter().enumerate() {
            let frame = req_frame(77 + i as u64, op);
            // Strip the length prefix + type byte, as the read loop does.
            let (id, back) = parse_req(&frame[5..]).unwrap();
            assert_eq!(id, 77 + i as u64);
            assert_eq!(back.kind, op.kind);
            assert_eq!(back.window, op.window);
            assert_eq!(back.root, op.root);
            assert_eq!(back.m, op.m);
            assert_eq!(back.blocks, op.blocks);
            assert_eq!(back.algo, op.algo);
            assert_eq!(back.data_seed, op.data_seed);
            assert_eq!(frame[4], FT_REQ);
        }
    }

    #[test]
    fn summary_frames_roundtrip_including_time_bits() {
        let s = OpSummary {
            digest: 0x1234_5678_9ABC_DEF0,
            complete: true,
            algo: Algo::Binomial,
            rounds: 11,
            active_rounds: 9,
            messages: 140,
            bytes: 11_200,
            max_rank_bytes: 960,
            time: 12.625e-6,
        };
        let frame = res_ok_frame(9, &s);
        let (id, back) = parse_res_ok(&frame[5..]).unwrap();
        assert_eq!(id, 9);
        assert_eq!(back, s);
        assert_eq!(back.time.to_bits(), s.time.to_bits());
    }

    #[test]
    fn hello_reject_and_err_frames_roundtrip() {
        let t = parse_chello(&chello_frame("tenant-a")[5..]).unwrap();
        assert_eq!(t, "tenant-a");
        assert!(parse_chello(&chello_frame("")[5..]).is_err(), "empty tenant refused");
        let p = parse_shello(&shello_frame(256)[5..]).unwrap();
        assert_eq!(p, 256);
        let (id, msg) = parse_res_err(&res_err_frame(3, "bad request: nope")[5..]).unwrap();
        assert_eq!((id, msg.as_str()), (3, "bad request: nope"));
        let (id, ms) = parse_res_reject(&res_reject_frame(8, 5)[5..]).unwrap();
        assert_eq!((id, ms), (8, 5));
        let text = parse_stats_res(&stats_res_frame("ops=4")[5..]).unwrap();
        assert_eq!(text, "ops=4");
    }

    #[test]
    fn digests_distinguish_shape_and_content() {
        let a = mix_digest(&[vec![1], vec![]]);
        let b = mix_digest(&[vec![], vec![1]]);
        let c = mix_digest(&[vec![1], vec![]]);
        assert_ne!(a, b);
        assert_eq!(a, c);
        assert_ne!(mix_digest(&[vec![1, 2]]), mix_digest(&[vec![2, 1]]));
    }

    #[test]
    fn unknown_codes_are_invalid_data() {
        assert!(kind_from(9).is_err());
        assert!(algo_from(9).is_err());
        for k in [Kind::Bcast, Kind::Reduce, Kind::Allgatherv, Kind::ReduceScatter, Kind::Allreduce]
        {
            assert_eq!(kind_from(kind_code(k)).unwrap(), k);
        }
        for a in [
            Algo::Auto,
            Algo::Circulant,
            Algo::Binomial,
            Algo::VanDeGeijn,
            Algo::Ring,
            Algo::RecursiveHalving,
            Algo::OptTree,
        ] {
            assert_eq!(algo_from(algo_code(a)).unwrap(), a);
        }
    }
}
