//! The collective service: a long-lived daemon over the wire plane.
//!
//! The wire plane ([`crate::comm::socket`]) moves *ranks* across
//! sockets; this module moves *requests*. A daemon
//! ([`serve_unix`] / [`serve_tcp`], or the `cbcastd` binary) owns one
//! [`crate::comm::Communicator`] and accepts concurrent client
//! connections over the same length-prefixed framing. Each client
//! identifies a **tenant** in its hello, then submits collective
//! *specifications* — kind, window, root, size, block count,
//! algorithm, data seed ([`crate::testkit::MixOp`]); payload buffers
//! never cross the wire, both sides derive them from the seed. The
//! daemon gathers concurrently-arriving requests into one traffic-plane
//! batch ([`crate::comm::TrafficEngine`]), so interleaved client work
//! round-shares the machine under the cross-op one-ported port ledger,
//! and replies per op with a result digest + the full statistics line
//! ([`OpSummary`]) — enough for any client to assert bit-identity
//! against a solo run of the same spec.
//!
//! **Admission control** is explicit: the handler→batcher queue is
//! bounded, and a request hitting the bound is refused immediately with
//! a `retry_after` hint ([`ServiceReply::Rejected`]) instead of
//! queueing unboundedly. Refusals, like completed work, are charged to
//! the tenant's usage row ([`crate::comm::TenantUsage`]) in the batch
//! report.
//!
//! The one-ported round discipline holds end to end: every admitted op
//! executes on the engine's port ledger, so nothing the daemon batches
//! can ever schedule two sends (or two receives) on one rank in one
//! machine round — the same invariant the lockstep simulator enforces.

mod client;
mod daemon;
mod wire;

pub use client::{ServiceClient, DEFAULT_ADMIT_ATTEMPTS};
pub use daemon::{
    serve_tcp, serve_unix, ServiceConfig, ServiceHandle, ServiceMetrics, MAX_OP_M,
};
pub use wire::{mix_digest, summarize, OpSummary, ServiceReply};

#[cfg(test)]
mod tests {
    use std::path::PathBuf;
    use std::time::Duration;

    use crate::comm::CommBuilder;
    use crate::testkit::{run_mix_blocking, traffic_mix, MixOptions, Rng};

    use super::*;

    fn temp_sock(tag: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("cbcastd-test-{tag}-{}.sock", std::process::id()));
        p
    }

    fn test_config(p: usize) -> ServiceConfig {
        ServiceConfig {
            p,
            client_timeout: Duration::from_millis(500),
            ..ServiceConfig::default()
        }
    }

    #[test]
    fn daemon_replies_match_solo_runs() {
        let p = 16usize;
        let path = temp_sock("parity");
        let handle = serve_unix(&path, test_config(p)).unwrap();
        let mut client =
            ServiceClient::connect_unix_retry(&path, "solo", Duration::from_secs(5)).unwrap();
        assert_eq!(client.p(), p);

        let mix = traffic_mix(&mut Rng::new(0xC0FFEE), p, 12, &MixOptions::default());
        for (i, op) in mix.ops.iter().enumerate() {
            let reply = client.call_admitted(i as u64, op).unwrap();
            let solo = run_mix_blocking(&CommBuilder::new(op.ranks(p)).build(), op);
            match (reply, summarize(&solo)) {
                (ServiceReply::Ok(got), Ok(want)) => assert_eq!(got, want, "op #{i}: {op:?}"),
                (ServiceReply::Err(got), Err(want)) => assert_eq!(got, want, "op #{i}: {op:?}"),
                (got, want) => panic!("op #{i}: daemon said {got:?}, solo said {want:?}"),
            }
        }
        let stats = client.stats().unwrap();
        assert!(stats.contains("tenant=solo"), "stats must bill the tenant: {stats}");
        client.bye().unwrap();
        handle.shutdown();
        let metrics = handle.join();
        assert_eq!(metrics.admitted, 12);
        assert_eq!(metrics.completed + metrics.failed, 12);
        let row = metrics.tenants.iter().find(|t| t.tenant == "solo").unwrap();
        assert_eq!(row.ops, 12);
    }

    #[test]
    fn saturated_queue_rejects_with_retry_hint() {
        // A one-slot queue and a long gather window: the batcher sits in
        // its gather sleep while we stuff the queue, so all but the
        // admitted request are refused — then succeed on resubmission.
        let path = temp_sock("reject");
        let cfg = ServiceConfig {
            p: 8,
            queue_cap: 1,
            gather: Duration::from_millis(300),
            retry_after: Duration::from_millis(2),
            client_timeout: Duration::from_millis(500),
            ..ServiceConfig::default()
        };
        let handle = serve_unix(&path, cfg).unwrap();
        let mut client =
            ServiceClient::connect_unix_retry(&path, "greedy", Duration::from_secs(5)).unwrap();
        let mix = traffic_mix(&mut Rng::new(7), 8, 6, &MixOptions::default());

        // Pipeline all six without waiting: at most one fits the queue.
        for (i, op) in mix.ops.iter().enumerate() {
            client.submit(i as u64, op).unwrap();
        }
        let mut rejected = Vec::new();
        let mut done = 0usize;
        while done < mix.ops.len() {
            let (id, reply) = client.recv_reply().unwrap();
            match reply {
                ServiceReply::Rejected { retry_after_ms } => {
                    assert!(retry_after_ms >= 1);
                    rejected.push(id);
                }
                ServiceReply::Ok(_) | ServiceReply::Err(_) => done += 1,
            }
            // Resubmit rejected ops once replies start flowing (the
            // batcher has drained the queue by then).
            if done > 0 {
                for id in rejected.drain(..) {
                    client.submit(id, &mix.ops[id as usize]).unwrap();
                }
            }
        }
        handle.shutdown();
        let metrics = handle.join();
        assert!(metrics.rejected >= 1, "a one-slot queue must refuse pipelined work");
        assert_eq!(metrics.completed + metrics.failed, 6);
        let row = metrics.tenants.iter().find(|t| t.tenant == "greedy").unwrap();
        assert!(row.rejected >= 1, "refusals must be billed to the tenant: {row:?}");
    }

    #[test]
    fn oversized_ops_fail_without_poisoning_the_connection() {
        let path = temp_sock("cap");
        let handle = serve_unix(&path, test_config(4)).unwrap();
        let mut client =
            ServiceClient::connect_unix_retry(&path, "big", Duration::from_secs(5)).unwrap();
        let mut mix = traffic_mix(&mut Rng::new(3), 4, 2, &MixOptions::default());
        mix.ops[0].m = MAX_OP_M + 1;
        match client.call_admitted(0, &mix.ops[0]).unwrap() {
            ServiceReply::Err(msg) => assert!(msg.contains("exceeds daemon cap"), "{msg}"),
            other => panic!("oversized op must fail, got {other:?}"),
        }
        // The connection (and the daemon) keep serving.
        let reply = client.call_admitted(1, &mix.ops[1]).unwrap();
        assert!(!matches!(reply, ServiceReply::Rejected { .. }));
        handle.shutdown();
        handle.join();
    }

    #[test]
    fn zero_capacity_daemon_exhausts_admission_budget() {
        // The pre-fix client retried admission refusals forever; against
        // a zero-capacity queue that was an infinite loop. The bounded
        // budget must surface a typed "admission exhausted" error.
        let path = temp_sock("zerocap");
        let cfg = ServiceConfig {
            p: 4,
            queue_cap: 0,
            retry_after: Duration::from_millis(1),
            client_timeout: Duration::from_millis(500),
            ..ServiceConfig::default()
        };
        let handle = serve_unix(&path, cfg).unwrap();
        let mut client =
            ServiceClient::connect_unix_retry(&path, "starved", Duration::from_secs(5)).unwrap();
        let mix = traffic_mix(&mut Rng::new(11), 4, 1, &MixOptions::default());
        let err = client.call_admitted_budget(0, &mix.ops[0], 4).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");
        assert!(err.to_string().contains("admission exhausted"), "{err}");
        handle.shutdown();
        let metrics = handle.join();
        assert_eq!(metrics.admitted, 0, "nothing fits a zero-capacity queue");
        assert_eq!(metrics.rejected, 4, "every attempt in the budget was refused");
    }

    #[test]
    fn distinct_tenants_follow_distinct_jitter_schedules() {
        use super::client::{backoff_schedule, tenant_seed};

        // Two tenants against the same zero-capacity daemon: both must
        // exhaust the same bounded budget (the jitter changes *when*
        // they resubmit, never *how often*)...
        let path = temp_sock("jitter");
        let cfg = ServiceConfig {
            p: 4,
            queue_cap: 0,
            retry_after: Duration::from_millis(1),
            client_timeout: Duration::from_millis(500),
            ..ServiceConfig::default()
        };
        let handle = serve_unix(&path, cfg).unwrap();
        let mix = traffic_mix(&mut Rng::new(21), 4, 1, &MixOptions::default());
        for tenant in ["jitter-a", "jitter-b"] {
            let mut client =
                ServiceClient::connect_unix_retry(&path, tenant, Duration::from_secs(5))
                    .unwrap();
            let err = client.call_admitted_budget(0, &mix.ops[0], 4).unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{tenant}: {err}");
        }
        handle.shutdown();
        let metrics = handle.join();
        assert_eq!(metrics.admitted, 0);
        assert_eq!(metrics.rejected, 8, "both tenants spent the full budget");

        // ...while their sleep schedules are deterministic per tenant,
        // distinct across tenants, and bounded to 50-100% of the capped
        // doubling base.
        let (sa, sb) = (tenant_seed("jitter-a"), tenant_seed("jitter-b"));
        assert_ne!(sa, sb);
        let sched_a = backoff_schedule(5, 8, sa);
        let sched_b = backoff_schedule(5, 8, sb);
        assert_eq!(sched_a, backoff_schedule(5, 8, sa), "schedules replay per tenant");
        assert_ne!(sched_a, sched_b, "distinct tenants must desynchronize");
        assert_eq!(sched_a.len(), 7, "one sleep between consecutive submissions");
        for (i, d) in sched_a.iter().enumerate() {
            let base = Duration::from_millis(5)
                .saturating_mul(1u32 << i.min(8) as u32)
                .min(Duration::from_millis(500));
            assert!(*d <= base, "sleep #{i} {d:?} above its base {base:?}");
            assert!(*d >= base / 2, "sleep #{i} {d:?} below half its base {base:?}");
        }
    }

    #[test]
    fn chaos_knob_probes_at_startup_and_reports_wire_counters() {
        use crate::comm::FaultPlan;

        // A healable plan: the daemon self-probes a lossy two-rank
        // chaos-socket world at startup, heals it, and serves normally
        // with the wire counters on its stats line.
        let path = temp_sock("chaosknob");
        let cfg = ServiceConfig {
            p: 4,
            client_timeout: Duration::from_millis(500),
            chaos: Some(FaultPlan::new(0xCAFE).drop_per_10k(1_500).corrupt_per_10k(1_500, 3)),
            ..ServiceConfig::default()
        };
        let handle = serve_unix(&path, cfg).unwrap();
        let mut client =
            ServiceClient::connect_unix_retry(&path, "chaos", Duration::from_secs(5)).unwrap();
        let mix = traffic_mix(&mut Rng::new(5), 4, 2, &MixOptions::default());
        for (i, op) in mix.ops.iter().enumerate() {
            let reply = client.call_admitted(i as u64, op).unwrap();
            assert!(!matches!(reply, ServiceReply::Rejected { .. }));
        }
        let stats = client.stats().unwrap();
        assert!(stats.contains("wire: retransmits="), "{stats}");
        handle.shutdown();
        let metrics = handle.join();
        assert_eq!(metrics.completed + metrics.failed, 2);
        assert_eq!(metrics.recoveries, 0, "a healable plan consumes no epoch");
        assert_eq!(metrics.epoch, 0);

        // An unhealable plan (a blackholed link exhausts the retry
        // budget) must be refused at startup, not discovered in service.
        let err = serve_unix(
            &temp_sock("chaosknob-hostile"),
            ServiceConfig {
                p: 4,
                chaos: Some(FaultPlan::new(1).blackhole(1)),
                ..ServiceConfig::default()
            },
        )
        .unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput, "{err}");
        assert!(err.to_string().contains("chaos self-probe"), "{err}");
    }

    #[test]
    fn vanished_client_loses_only_its_reply() {
        // The daemon deliberately ignores reply-write failures
        // (`send_frame`): a client that drops mid-batch hits the write
        // with a broken pipe. Pin that the failure stays contained —
        // the co-batched client's digest is still correct, the ghost's
        // op still runs, and both tenants are still billed.
        let path = temp_sock("vanish");
        let cfg = ServiceConfig {
            p: 8,
            gather: Duration::from_millis(300),
            client_timeout: Duration::from_millis(500),
            ..ServiceConfig::default()
        };
        let handle = serve_unix(&path, cfg).unwrap();
        let mix = traffic_mix(&mut Rng::new(0xDEAD), 8, 2, &MixOptions::default());

        let mut ghost =
            ServiceClient::connect_unix_retry(&path, "ghost", Duration::from_secs(5)).unwrap();
        let mut stayer =
            ServiceClient::connect_unix_retry(&path, "stayer", Duration::from_secs(5)).unwrap();
        // Both land in the same 300 ms gather window...
        ghost.submit(0, &mix.ops[0]).unwrap();
        stayer.submit(1, &mix.ops[1]).unwrap();
        // ...then the ghost vanishes before its reply can be written
        // (the submitted frame stays readable in the socket buffer, so
        // the op is still admitted).
        drop(ghost);

        let (id, reply) = stayer.recv_reply().unwrap();
        assert_eq!(id, 1);
        let solo = run_mix_blocking(&CommBuilder::new(mix.ops[1].ranks(8)).build(), &mix.ops[1]);
        match (reply, summarize(&solo)) {
            (ServiceReply::Ok(got), Ok(want)) => assert_eq!(got, want),
            (ServiceReply::Err(got), Err(want)) => assert_eq!(got, want),
            (got, want) => panic!("stayer got {got:?}, solo said {want:?}"),
        }
        handle.shutdown();
        let metrics = handle.join();
        assert_eq!(metrics.admitted, 2);
        assert_eq!(
            metrics.completed + metrics.failed,
            2,
            "the ghost's op still ran and was counted: {metrics:?}"
        );
        let row = metrics.tenants.iter().find(|t| t.tenant == "ghost").unwrap();
        assert_eq!(row.ops, 1, "the vanished tenant is still billed: {row:?}");
    }

    #[test]
    fn daemon_recovers_from_a_dead_rank_mid_service() {
        use crate::comm::request::{Algo, Kind};
        use crate::testkit::MixOp;

        let p = 8usize;
        let bcast = |root: usize, window, seed: u64| MixOp {
            kind: Kind::Bcast,
            window,
            root,
            m: 48,
            blocks: None,
            algo: Algo::Auto,
            data_seed: seed,
        };
        let path = temp_sock("recover");
        let cfg = ServiceConfig {
            p,
            client_timeout: Duration::from_millis(500),
            // Rank 3 dies immediately before batch #1 executes.
            fault: Some((3, 1)),
            ..ServiceConfig::default()
        };
        let handle = serve_unix(&path, cfg).unwrap();
        let mut client =
            ServiceClient::connect_unix_retry(&path, "elastic", Duration::from_secs(5))
                .unwrap();

        // Batch 0: the full 8-rank world serves as usual.
        let op0 = bcast(0, None, 101);
        let want0 = summarize(&run_mix_blocking(&CommBuilder::new(p).build(), &op0)).unwrap();
        match client.call_admitted(0, &op0).unwrap() {
            ServiceReply::Ok(got) => assert_eq!(got, want0),
            other => panic!("pre-fault op must succeed, got {other:?}"),
        }

        // Batch 1: rank 3 dies first. The daemon shrinks to the 7-rank
        // survivor world and re-admits the queued op there — the reply
        // must be bit-identical to a fresh solo run at p = 7 (root 0
        // survived, and with the dense renumbering the whole-machine
        // spec is unchanged).
        let op1 = bcast(0, None, 202);
        let want1 = summarize(&run_mix_blocking(&CommBuilder::new(p - 1).build(), &op1)).unwrap();
        match client.call_admitted(1, &op1).unwrap() {
            ServiceReply::Ok(got) => assert_eq!(got, want1, "survivor world must match fresh p-1"),
            other => panic!("post-fault op must succeed on the shrunken world, got {other:?}"),
        }

        // A dead root is replaced by the lowest surviving rank (global
        // 0 -> dense 0).
        let op2 = bcast(3, None, 303);
        let mut op2_remap = op2.clone();
        op2_remap.root = 0;
        let want2 =
            summarize(&run_mix_blocking(&CommBuilder::new(p - 1).build(), &op2_remap)).unwrap();
        match client.call_admitted(2, &op2).unwrap() {
            ServiceReply::Ok(got) => assert_eq!(got, want2, "dead root must be re-elected"),
            other => panic!("dead-root op must succeed under the new root, got {other:?}"),
        }

        // A window whose every rank died has no world left.
        let op3 = bcast(0, Some((3, 1)), 404);
        match client.call_admitted(3, &op3).unwrap() {
            ServiceReply::Err(msg) => assert!(msg.contains("lost every rank"), "{msg}"),
            other => panic!("a vanished window must fail, got {other:?}"),
        }

        let stats = client.stats().unwrap();
        assert!(stats.contains("recoveries=1"), "{stats}");
        assert!(stats.contains("epoch=1"), "{stats}");
        handle.shutdown();
        let metrics = handle.join();
        assert_eq!(metrics.recoveries, 1);
        assert_eq!(metrics.epoch, 1);
        let row = metrics.tenants.iter().find(|t| t.tenant == "elastic").unwrap();
        assert!(row.restarted >= 1, "the disruption must be billed: {row:?}");
    }

    #[test]
    fn client_shutdown_frame_stops_the_daemon() {
        let path = temp_sock("shutdown");
        let handle = serve_unix(&path, test_config(4)).unwrap();
        let client =
            ServiceClient::connect_unix_retry(&path, "admin", Duration::from_secs(5)).unwrap();
        client.shutdown_daemon().unwrap();
        // join() returns only because the shutdown frame stopped every
        // thread; a hang here is the failure.
        handle.join();
    }
}
