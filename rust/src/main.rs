//! `cbcast` — CLI for the circulant-broadcast collectives engine.
//!
//! ```text
//! cbcast schedule -p 17 [-r RANK]          print recv/send schedule table
//! cbcast verify -p LO[..HI] [--sample N]   machine-check the 4 conditions
//! cbcast run KIND -p P -m M [options]      simulate a collective
//!      KIND: bcast | reduce | allgatherv | reduce-scatter | allreduce
//!      --root R --blocks N|auto
//!      --algo auto|circulant|binomial|vdg|ring|rhalving
//!      --dist regular|irregular|degenerate
//!      --cost unit|linear[:a:b]|vega:CORES|cluster:CORES
//! cbcast artifacts [--dir D]               list + compile AOT artifacts
//! cbcast serve                             line-based request loop (stdin)
//! ```
//!
//! (Hand-rolled argument parsing: the image has no network access and the
//! vendored crate set does not include clap.)

use std::sync::Arc;

use circulant_bcast::coordinator::{parse_cost, Algo, Dist, Engine, Kind, Request};
use circulant_bcast::runtime::XlaRuntime;
use circulant_bcast::schedule::{recv_schedule, send_schedule, verify_all, verify_sampled, Skips};
use circulant_bcast::sim::cost::CostModel;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("schedule") => cmd_schedule(&args[1..]),
        Some("verify") => cmd_verify(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("artifacts") => cmd_artifacts(&args[1..]),
        Some("serve") => cmd_serve(),
        Some("help") | None => {
            print_help();
            0
        }
        Some(other) => {
            eprintln!("unknown command {other:?}; try `cbcast help`");
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!("cbcast — round-optimal broadcast schedules (Träff 2024) and collectives");
    println!("commands: schedule, verify, run, artifacts, serve, help");
    println!("see the header of rust/src/main.rs or README.md for options");
}

/// Tiny flag parser: returns the value following `name`.
fn opt<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).map(|s| s.as_str())
}

fn opt_usize(args: &[String], name: &str, default: usize) -> usize {
    opt(args, name).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn cmd_schedule(args: &[String]) -> i32 {
    let p = opt_usize(args, "-p", 17);
    let sk = Skips::new(p);
    let q = sk.q();
    println!("p = {p}, q = {q}, skips = {:?}", sk.as_slice());
    let ranks: Vec<usize> = match opt(args, "-r") {
        Some(r) => vec![r.parse().unwrap_or(0)],
        None => (0..p).collect(),
    };
    // Header like the paper's Table 1.
    print!("{:<14}", "r:");
    for &r in &ranks {
        print!("{r:>5}");
    }
    println!();
    let recvs: Vec<_> = ranks.iter().map(|&r| recv_schedule(&sk, r)).collect();
    let sends: Vec<_> = ranks.iter().map(|&r| send_schedule(&sk, r)).collect();
    print!("{:<14}", "b:");
    for s in &recvs {
        print!("{:>5}", s.baseblock);
    }
    println!();
    for k in 0..q {
        print!("recvblock[{k}]: ");
        for s in &recvs {
            print!("{:>5}", s.blocks[k]);
        }
        println!();
    }
    for k in 0..q {
        print!("sendblock[{k}]: ");
        for s in &sends {
            print!("{:>5}", s.blocks[k]);
        }
        println!();
    }
    0
}

fn cmd_verify(args: &[String]) -> i32 {
    let spec = opt(args, "-p").unwrap_or("2..64");
    let (lo, hi) = match spec.split_once("..") {
        Some((a, b)) => (a.parse().unwrap_or(2), b.parse().unwrap_or(64)),
        None => {
            let v: usize = spec.parse().unwrap_or(17);
            (v, v)
        }
    };
    let sample = opt(args, "--sample").and_then(|v| v.parse::<usize>().ok());
    let mut worst_viol = 0usize;
    for p in lo..=hi {
        let rep = if let Some(k) = sample {
            let ranks: Vec<usize> = (0..k).map(|i| (i * 2654435761) % p).collect();
            verify_sampled(p, &ranks)
        } else {
            verify_all(p)
        };
        if !rep.ok() {
            eprintln!("p={p}: FAILED: {:?}", &rep.failures[..rep.failures.len().min(3)]);
            return 1;
        }
        worst_viol = worst_viol.max(rep.max_violations);
    }
    println!(
        "verified p in {lo}..={hi}{}: all four conditions hold; max send-schedule \
         violations per rank = {worst_viol} (Theorem 3 bound: 4)",
        if sample.is_some() { " (sampled)" } else { "" }
    );
    0
}

fn cmd_run(args: &[String]) -> i32 {
    let Some(kind) = args.first().and_then(|k| Kind::parse(k)) else {
        eprintln!("run: first arg must be a collective kind");
        return 2;
    };
    let p = opt_usize(args, "-p", 16);
    let m = opt_usize(args, "-m", 1 << 16);
    let mut req = Request::new(kind, p, m);
    req.root = opt_usize(args, "--root", 0);
    req.elem_bytes = opt_usize(args, "--elem-bytes", 4);
    if let Some(b) = opt(args, "--blocks") {
        if b != "auto" {
            req.blocks = b.parse().ok();
        }
    }
    if let Some(a) = opt(args, "--algo") {
        match Algo::parse(a) {
            Some(a) => req.algo = a,
            None => {
                eprintln!("unknown algo {a:?}");
                return 2;
            }
        }
    }
    if let Some(d) = opt(args, "--dist") {
        match Dist::parse(d) {
            Some(d) => req.dist = d,
            None => {
                eprintln!("unknown dist {d:?}");
                return 2;
            }
        }
    }
    let cost: Box<dyn CostModel> = match parse_cost(opt(args, "--cost").unwrap_or("linear")) {
        Some(c) => c,
        None => {
            eprintln!("bad --cost spec");
            return 2;
        }
    };
    let engine = Engine::new();
    match engine.run(&req, cost.as_ref()) {
        Ok(rep) => {
            println!(
                "{kind:?} p={p} m={m} algo={:?} dist={:?} n={} q={} rounds={} msgs={} \
                 bytes={} sim_time={:.6}s wall={:.3}ms valid={}",
                rep.plan.algo,
                req.dist,
                rep.plan.n,
                rep.plan.q,
                rep.stats.rounds,
                rep.stats.messages,
                rep.stats.bytes,
                rep.sim_time,
                rep.wall * 1e3,
                rep.valid
            );
            i32::from(!rep.valid)
        }
        Err(e) => {
            eprintln!("run failed: {e}");
            1
        }
    }
}

fn cmd_artifacts(args: &[String]) -> i32 {
    let dir = opt(args, "--dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(circulant_bcast::runtime::default_dir);
    match XlaRuntime::with_dir(&dir) {
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            for a in rt.artifacts() {
                println!(
                    "  {:?} op={} dtype={:?} shape={:?} ({})",
                    a.kind,
                    a.op,
                    a.dtype,
                    a.shape,
                    a.path.file_name().unwrap().to_string_lossy()
                );
            }
            let n = rt.compile_all().expect("compile");
            println!("compiled {n} artifacts OK");
            0
        }
        Err(e) => {
            eprintln!("artifacts: {e}");
            1
        }
    }
}

/// Line-based request loop: one request per line, e.g.
/// `bcast p=1000 m=65536 blocks=auto algo=circulant cost=linear`.
fn cmd_serve() -> i32 {
    use std::io::BufRead;
    let engine = Arc::new(Engine::new());
    let stdin = std::io::stdin();
    println!("cbcast serve: one request per line; `metrics`, `quit`");
    for line in stdin.lock().lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "quit" {
            break;
        }
        if line == "metrics" {
            print!("{}", engine.metrics.render());
            continue;
        }
        match parse_serve_line(line) {
            Some((req, cost)) => match engine.run(&req, cost.as_ref()) {
                Ok(rep) => println!(
                    "ok kind={:?} n={} rounds={} bytes={} sim_time={:.6} valid={}",
                    req.kind, rep.plan.n, rep.stats.rounds, rep.stats.bytes, rep.sim_time, rep.valid
                ),
                Err(e) => println!("error: {e}"),
            },
            None => println!("parse error: {line:?}"),
        }
    }
    0
}

fn parse_serve_line(line: &str) -> Option<(Request, Box<dyn CostModel>)> {
    let mut words = line.split_whitespace();
    let kind = Kind::parse(words.next()?)?;
    let mut req = Request::new(kind, 16, 1 << 16);
    let mut cost: Box<dyn CostModel> = parse_cost("linear").unwrap();
    for w in words {
        let (k, v) = w.split_once('=')?;
        match k {
            "p" => req.p = v.parse().ok()?,
            "m" => req.m = v.parse().ok()?,
            "root" => req.root = v.parse().ok()?,
            "blocks" => {
                if v != "auto" {
                    req.blocks = Some(v.parse().ok()?);
                }
            }
            "algo" => req.algo = Algo::parse(v)?,
            "dist" => req.dist = Dist::parse(v)?,
            "cost" => cost = parse_cost(v)?,
            "elem_bytes" => req.elem_bytes = v.parse().ok()?,
            _ => return None,
        }
    }
    Some((req, cost))
}
