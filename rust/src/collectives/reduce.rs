//! Rooted reduction via reversed broadcast schedules — Observation 1.3 of
//! the paper, the round-optimal `MPI_Reduce` for commutative operators.
//!
//! The broadcast communication pattern of Algorithm 1 is run *backwards*:
//! network round `j` of the reduction corresponds to broadcast round
//! `total - 1 - j`, with every edge reversed. Where broadcast moved block
//! `recvblock[k]_r` from `f_r^k` to `r`, reduction moves the partial
//! result of that block from `r` to `f_r^k`; the receiver combines it into
//! its own partial block with the operator ⊕. Every non-root processor
//! sends each partial block exactly once, and the reversed-time order
//! guarantees all contributions to a block arrive before that block is
//! forwarded — the root ends with the full reduction over all `p` ranks.
//!
//! The front door for running this collective is
//! [`crate::comm::Communicator::reduce`].

use std::sync::Arc;

use crate::sim::network::{Msg, RankProc};

use super::common::{BlockGeometry, Element, PhasedSchedule, ReduceOp, ScheduleSource, World};

/// Per-rank state machine for the reversed-schedule reduction.
pub struct ReduceProc<T> {
    pub rank: usize,
    root: usize,
    ps: PhasedSchedule,
    geom: BlockGeometry,
    op: Arc<dyn ReduceOp<T>>,
    /// The rank's partial result, block by block (accumulated in place).
    blocks: Vec<Vec<T>>,
}

impl<T: Element> ReduceProc<T> {
    /// Every rank contributes a full `geom.m`-element buffer.
    pub fn new(
        world: &World,
        rank: usize,
        root: usize,
        geom: BlockGeometry,
        data: &[T],
        op: Arc<dyn ReduceOp<T>>,
    ) -> Self {
        let ps = super::common::phased_for(&world.sk, rank, root, geom.n);
        Self::with_schedule(ps, rank, root, geom, data, op)
    }

    /// Build from an already-computed [`PhasedSchedule`] (the
    /// cache-served path used by [`crate::comm::Communicator`]).
    pub fn with_schedule(
        ps: PhasedSchedule,
        rank: usize,
        root: usize,
        geom: BlockGeometry,
        data: &[T],
        op: Arc<dyn ReduceOp<T>>,
    ) -> Self {
        assert_eq!(data.len(), geom.m);
        assert_eq!(ps.n, geom.n, "schedule phased for a different block count");
        let blocks = (0..geom.n)
            .map(|b| {
                let (off, len) = geom.range(b);
                data[off..off + len].to_vec()
            })
            .collect();
        ReduceProc { rank, root, ps, geom, op, blocks }
    }

    /// The broadcast round mirrored by network round `j`.
    #[inline]
    fn fwd_round(&self, j: usize) -> usize {
        self.ps.rounds() - 1 - j
    }

    /// The root's final buffer (only meaningful at the root).
    pub fn into_buffer(self) -> Vec<T> {
        assert_eq!(self.rank, self.root, "only the root holds the reduction result");
        let mut out = Vec::with_capacity(self.geom.m);
        for blk in self.blocks {
            out.extend_from_slice(&blk);
        }
        out
    }
}

impl<T: Element> RankProc<T> for ReduceProc<T> {
    fn send(&mut self, j: usize) -> Option<Msg<T>> {
        // Reversal of the broadcast *receive*: send our accumulated
        // partial of recvblock[k] to the from-processor.
        if self.ps.rel == 0 {
            return None; // the root never sends in reduction
        }
        let i = self.fwd_round(j);
        let b = self.ps.cap(self.ps.recv_at(i))?;
        let k = self.ps.slot(i);
        let to = (self.rank + self.ps.p - self.ps.skip(k)) % self.ps.p;
        Some(Msg { to, data: self.blocks[b].clone() })
    }

    fn expects(&self, j: usize) -> Option<usize> {
        // Reversal of the broadcast *send*: receive a partial of
        // sendblock[k] from the to-processor (unless that send was
        // suppressed because it would have targeted the root — reversed:
        // the root's outgoing edges carry nothing, so WE, as the root's
        // from-processor... the suppression is on the broadcast sender
        // side t_rel == 0, i.e. on edges INTO the root; reversed, edges
        // out of the root carry nothing, so a rank whose to-processor is
        // the root receives nothing from it. t_rel == 0 is exactly that.)
        let i = self.fwd_round(j);
        let k = self.ps.slot(i);
        let t_rel = (self.ps.rel + self.ps.skip(k)) % self.ps.p;
        if t_rel == 0 {
            return None;
        }
        self.ps.cap(self.ps.send_at(i))?;
        Some((self.rank + self.ps.skip(k)) % self.ps.p)
    }

    fn recv(&mut self, j: usize, _from: usize, data: Vec<T>) {
        let i = self.fwd_round(j);
        let b = self
            .ps
            .cap(self.ps.send_at(i))
            .expect("recv called in a round with no scheduled (reversed) receive");
        debug_assert_eq!(data.len(), self.geom.len(b));
        self.op.combine(&mut self.blocks[b], &data);
    }

    fn rounds(&self) -> usize {
        self.ps.rounds()
    }
}

/// Build all `p` rank state machines from one schedule source — the
/// shared construction loop used by the [`crate::comm`] backends (the
/// SPMD plane builds one machine per rank instead: [`crate::comm::RankComm`]).
pub fn build_reduce_procs<T: Element>(
    src: &ScheduleSource<'_>,
    root: usize,
    geom: BlockGeometry,
    inputs: &[Vec<T>],
    op: Arc<dyn ReduceOp<T>>,
) -> Vec<ReduceProc<T>> {
    crate::comm::build_procs(src.p(), |r| {
        ReduceProc::with_schedule(
            src.phased(r, root, geom.n),
            r,
            root,
            geom,
            &inputs[r],
            op.clone(),
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::common::SumOp;
    use crate::comm::{Algo, Communicator, ReduceReq};
    use crate::sim::cost::UnitCost;

    fn check_reduce(p: usize, root: usize, m: usize, n: usize) {
        let inputs: Vec<Vec<i64>> = (0..p)
            .map(|r| (0..m).map(|i| (r * 1000 + i) as i64).collect())
            .collect();
        let expect: Vec<i64> = (0..m)
            .map(|i| inputs.iter().map(|v| v[i]).sum())
            .collect();
        let comm = Communicator::builder(p).cost_model(UnitCost).build();
        let out = comm
            .reduce(
                ReduceReq::new(root, &inputs, Arc::new(SumOp))
                    .algo(Algo::Circulant)
                    .blocks(n),
            )
            .unwrap();
        assert_eq!(out.buffers, expect, "p={p} root={root} m={m} n={n}");
        if p > 1 {
            let q = crate::schedule::ceil_log2(p);
            assert_eq!(out.stats.rounds, n - 1 + q);
        }
    }

    #[test]
    fn reduce_small_grid() {
        for p in 1..=20 {
            for n in [1usize, 2, 3, 5, 8] {
                check_reduce(p, 0, 64, n);
            }
        }
    }

    #[test]
    fn reduce_nonzero_roots() {
        for p in [5usize, 9, 17] {
            for root in 0..p {
                check_reduce(p, root, 33, 4);
            }
        }
    }

    #[test]
    fn reduce_paper_sizes() {
        check_reduce(17, 0, 1000, 13);
        check_reduce(18, 3, 512, 9);
    }

    #[test]
    fn reduce_block_boundaries() {
        for p in [9usize, 17] {
            let q = crate::schedule::ceil_log2(p);
            for n in [q - 1, q, q + 1, 2 * q, 2 * q + 1] {
                check_reduce(p, 0, 100, n);
            }
        }
    }

    #[test]
    fn reduce_larger_p() {
        for p in [31usize, 32, 33, 64, 100, 128, 129] {
            check_reduce(p, 0, 48, 5);
        }
    }

    #[test]
    fn reduce_max_operator() {
        use crate::collectives::common::MaxOp;
        let p = 13;
        let m = 40;
        let inputs: Vec<Vec<i64>> = (0..p)
            .map(|r| (0..m).map(|i| ((r * 7 + i * 3) % 97) as i64).collect())
            .collect();
        let expect: Vec<i64> =
            (0..m).map(|i| inputs.iter().map(|v| v[i]).max().unwrap()).collect();
        let comm = Communicator::builder(p).cost_model(UnitCost).build();
        let out = comm
            .reduce(ReduceReq::new(0, &inputs, Arc::new(MaxOp)).algo(Algo::Circulant).blocks(4))
            .unwrap();
        assert_eq!(out.buffers, expect);
    }
}
