//! Pipelined `n`-block broadcast on the circulant graph — Algorithm 1 of
//! the paper, the round-optimal `MPI_Bcast`.
//!
//! The root's `m`-element buffer is divided into `n` roughly equal blocks;
//! the collective completes in the optimal `n - 1 + ceil(log2 p)` rounds.
//! All processors run the *same* symmetric communication pattern; which
//! block flows on which edge in which round is fully determined by the
//! O(log p)-computed send/receive schedules — no metadata is communicated.
//!
//! The front door for running this collective is
//! [`crate::comm::Communicator::bcast`]; this module provides the
//! per-rank state machine ([`BcastProc`]) and the shared proc builder
//! ([`build_bcast_procs`]). (The legacy `bcast_sim`/`bcast_procs`
//! wrappers finished their deprecation cycle and were removed.)

use crate::schedule::Schedule;
use crate::sim::network::{Msg, RankProc};

use super::common::{BlockGeometry, Element, PhasedSchedule, ScheduleSource, World};

/// Per-rank state machine for Algorithm 1.
pub struct BcastProc<T> {
    /// Absolute rank.
    pub rank: usize,
    /// The broadcast root (kept for introspection/debug output).
    pub root: usize,
    ps: PhasedSchedule,
    geom: BlockGeometry,
    /// `blocks[b]` is `Some(data)` once block `b` is known. The root
    /// starts with all blocks.
    blocks: Vec<Option<Vec<T>>>,
}

impl<T: Element> BcastProc<T> {
    /// Build rank `rank`'s state machine. `data` must be `Some(buffer)` of
    /// `geom.m` elements at the root, `None` elsewhere.
    pub fn new(
        world: &World,
        rank: usize,
        root: usize,
        geom: BlockGeometry,
        data: Option<&[T]>,
    ) -> Self {
        let ps = super::common::phased_for(&world.sk, rank, root, geom.n);
        Self::with_schedule(ps, rank, root, geom, data)
    }

    /// Build from an already-computed [`PhasedSchedule`] (the
    /// cache-served path used by [`crate::comm::Communicator`]).
    pub fn with_schedule(
        ps: PhasedSchedule,
        rank: usize,
        root: usize,
        geom: BlockGeometry,
        data: Option<&[T]>,
    ) -> Self {
        assert_eq!(ps.n, geom.n, "schedule phased for a different block count");
        let blocks = if rank == root {
            let buf = data.expect("root must supply the broadcast buffer");
            assert_eq!(buf.len(), geom.m);
            (0..geom.n)
                .map(|b| {
                    let (off, len) = geom.range(b);
                    Some(buf[off..off + len].to_vec())
                })
                .collect()
        } else {
            assert!(data.is_none(), "non-root ranks start without data");
            vec![None; geom.n]
        };
        BcastProc { rank, root, ps, geom, blocks }
    }

    /// Reassemble the received buffer (all blocks must have arrived).
    pub fn into_buffer(self) -> Vec<T> {
        let mut out = Vec::with_capacity(self.geom.m);
        for (b, blk) in self.blocks.into_iter().enumerate() {
            let data = blk.unwrap_or_else(|| {
                panic!("rank {}: block {b} never received", self.rank)
            });
            debug_assert_eq!(data.len(), self.geom.len(b));
            out.extend_from_slice(&data);
        }
        out
    }

    /// True iff every block has been received.
    pub fn complete(&self) -> bool {
        self.blocks.iter().all(|b| b.is_some())
    }

    #[inline]
    fn p(&self) -> usize {
        self.ps.p
    }
}

impl<T: Element> RankProc<T> for BcastProc<T> {
    fn send(&mut self, round: usize) -> Option<Msg<T>> {
        let k = self.ps.slot(round);
        let t_rel = (self.ps.rel + self.ps.skip(k)) % self.p();
        if t_rel == 0 {
            // Never send to the root (it has everything).
            return None;
        }
        let b = self.ps.cap(self.ps.send_at(round))?;
        let to = (self.rank + self.ps.skip(k)) % self.p();
        let data = self.blocks[b]
            .as_ref()
            .unwrap_or_else(|| {
                panic!(
                    "rank {} (rel {}): scheduled to send block {b} in round {round} \
                     but it has not been received — schedule violation",
                    self.rank, self.ps.rel
                )
            })
            .clone();
        Some(Msg { to, data })
    }

    fn expects(&self, round: usize) -> Option<usize> {
        if self.ps.rel == 0 {
            return None; // the root receives nothing
        }
        self.ps.cap(self.ps.recv_at(round))?;
        let k = self.ps.slot(round);
        Some((self.rank + self.p() - self.ps.skip(k)) % self.p())
    }

    fn recv(&mut self, round: usize, _from: usize, data: Vec<T>) {
        let b = self
            .ps
            .cap(self.ps.recv_at(round))
            .expect("recv called in a round with no scheduled receive");
        debug_assert_eq!(data.len(), self.geom.len(b), "rank {} round {round}", self.rank);
        self.blocks[b] = Some(data);
    }

    fn rounds(&self) -> usize {
        self.ps.rounds()
    }
}

/// Build all `p` rank state machines from one schedule source — the one
/// shared construction loop used by the [`crate::comm`] backends (the
/// SPMD plane builds one machine per rank instead: [`crate::comm::RankComm`]).
pub fn build_bcast_procs<T: Element>(
    src: &ScheduleSource<'_>,
    root: usize,
    geom: BlockGeometry,
    data: &[T],
) -> Vec<BcastProc<T>> {
    crate::comm::build_procs(src.p(), |r| {
        BcastProc::with_schedule(
            src.phased(r, root, geom.n),
            r,
            root,
            geom,
            if r == root { Some(data) } else { None },
        )
    })
}

/// Convenience: schedule objects for every rank (used by inspection tools).
pub fn all_schedules(world: &World) -> Vec<Schedule> {
    (0..world.p()).map(|r| Schedule::compute(&world.sk, r)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{Algo, BcastReq, Communicator};
    use crate::sim::cost::UnitCost;

    fn check_bcast(p: usize, root: usize, m: usize, n: usize) {
        let data: Vec<u32> = (0..m as u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let comm = Communicator::builder(p).cost_model(UnitCost).build();
        let out = comm
            .bcast(BcastReq::new(root, &data).algo(Algo::Circulant).blocks(n).elem_bytes(4))
            .unwrap();
        assert!(out.all_received(), "p={p} root={root} m={m} n={n}");
        for (r, buf) in out.buffers.iter().enumerate() {
            assert_eq!(buf, &data, "p={p} root={root} m={m} n={n} rank={r}");
        }
        // Round optimality: n - 1 + ceil(log2 p) rounds.
        if p > 1 {
            let q = crate::schedule::ceil_log2(p);
            assert_eq!(out.stats.rounds, n - 1 + q, "p={p} n={n}");
        }
    }

    #[test]
    fn bcast_small_grid() {
        for p in 1..=20 {
            for n in [1usize, 2, 3, 5, 8] {
                check_bcast(p, 0, 64, n);
            }
        }
    }

    #[test]
    fn bcast_nonzero_roots() {
        for p in [5usize, 9, 17] {
            for root in 0..p {
                check_bcast(p, root, 33, 4);
            }
        }
    }

    #[test]
    fn bcast_paper_sizes() {
        check_bcast(17, 0, 1000, 13);
        check_bcast(9, 0, 1000, 7);
        check_bcast(18, 0, 1000, 10);
    }

    #[test]
    fn bcast_n_multiple_of_q() {
        // x = 0 cases and x > 0 cases around multiples of q.
        for p in [9usize, 17] {
            let q = crate::schedule::ceil_log2(p);
            for n in [q, q + 1, 2 * q, 2 * q + 1, 3 * q - 1] {
                check_bcast(p, 0, 128, n);
            }
        }
    }

    #[test]
    fn bcast_m_smaller_than_n() {
        // Degenerate: more blocks than elements (empty blocks allowed).
        check_bcast(9, 0, 3, 7);
        check_bcast(17, 2, 0, 4);
    }

    #[test]
    fn bcast_single_block_is_binomial_depth() {
        // n = 1: q rounds, like a binomial tree.
        for p in [2usize, 3, 8, 15, 16, 17] {
            let data = vec![7u32; 10];
            let comm = Communicator::builder(p).cost_model(UnitCost).build();
            let out = comm
                .bcast(BcastReq::new(0, &data).algo(Algo::Circulant).blocks(1))
                .unwrap();
            let q = crate::schedule::ceil_log2(p);
            assert_eq!(out.stats.rounds, q);
        }
    }

    #[test]
    fn bcast_larger_p() {
        for p in [31usize, 32, 33, 100, 127, 128, 129] {
            check_bcast(p, 0, 96, 6);
        }
    }
}
