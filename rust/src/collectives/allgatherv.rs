//! All-broadcast (`MPI_Allgatherv` / `MPI_Allgather`) — Algorithm 7 of
//! the paper: `p` simultaneous pipelined broadcasts, one per root, on the
//! same circulant pattern, completing in the optimal `n - 1 + q` rounds.
//!
//! Every rank `r` holds the receive schedule of relative rank
//! `(r - j) mod p` for each root `j`; in round `k` the blocks for all
//! roots are packed into a single message (skipping the to-processor's own
//! root and negative blocks) and unpacked symmetrically — both sides
//! compute the identical layout from the schedules, so no sizes or indices
//! are transmitted. Irregular (`v`) inputs just divide each root's count
//! into `n` roughly equal blocks; ranks contributing nothing are skipped
//! in packing entirely, which is what makes the degenerate cases fast.

use std::sync::Arc;

use crate::schedule::{ScheduleTable as RowTable, Skips};
use crate::sim::network::{Msg, RankProc};

use super::common::{BlockGeometry, Element, ScheduleSource, World};

/// Where an Algorithm-7 table's raw rows live: the shared all-ranks
/// [`RowTable`] (the god-view plane, built once per `p` and shared), or
/// a rank-locally computed arena (the SPMD plane: one processor's own
/// relative rows for every root — see
/// [`ScheduleTable::build_rank_local`]). Layout and values are
/// identical; only provenance differs.
enum Rows {
    Shared(Arc<RowTable>),
    Local { arena: Vec<i8>, q: usize },
}

impl Rows {
    #[inline]
    fn recv_raw(&self, rel: usize, k: usize) -> i8 {
        match self {
            Rows::Shared(t) => t.recv_raw(rel, k),
            Rows::Local { arena, q } => arena[rel * 2 * q + k],
        }
    }

    #[inline]
    fn send_raw(&self, rel: usize, k: usize) -> i8 {
        match self {
            Rows::Shared(t) => t.send_raw(rel, k),
            Rows::Local { arena, q } => arena[rel * 2 * q + q + k],
        }
    }
}

/// The Algorithm-7 view of the all-ranks schedule plane for one block
/// count `n`: the raw recv+send rows of every relative rank (shared
/// [`RowTable`] on the god-view path, rank-locally computed on the SPMD
/// path — see [`crate::schedule::table`] and
/// [`ScheduleTable::build_rank_local`]) plus the `n`-dependent phase
/// bookkeeping. Building the shared flavour is O(1) beyond the row
/// table (which the cache builds in parallel once per `p`), so per-`n`
/// tables are cheap to memoize per communicator.
pub struct ScheduleTable {
    pub sk: Arc<Skips>,
    /// All relative ranks' raw schedule rows (`n`-agnostic).
    rows: Rows,
    /// Blocks per root.
    pub n: usize,
    /// Virtual-round offset.
    pub x: usize,
}

impl ScheduleTable {
    pub fn build(world: &World, n: usize) -> Arc<Self> {
        Self::build_from(&ScheduleSource::Direct(&world.sk), n)
    }

    /// Build from a [`ScheduleSource`] — on the table/cached paths (the
    /// [`crate::comm::Communicator`]), the all-ranks row table is shared
    /// instead of recomputed.
    pub fn build_from(src: &ScheduleSource<'_>, n: usize) -> Arc<Self> {
        assert!(n > 0);
        let rows = src.rows();
        let sk = rows.skips().clone();
        let q = sk.q();
        let x = if q == 0 { 0 } else { (q - (n - 1) % q) % q };
        Arc::new(ScheduleTable { sk, rows: Rows::Shared(rows), n, x })
    }

    /// Rank-local build for the SPMD plane ([`crate::comm::RankComm`]):
    /// Algorithm 7 has each processor hold, *for every root `j`*, its
    /// own receive/send schedule at relative position `(r - j) mod p` —
    /// and as `j` sweeps the roots, that position sweeps all `p`
    /// relative ranks. So the rank-local precompute is this processor's
    /// own row for each of the `p` concurrent broadcasts, filled here
    /// with the per-rank O(log p) cores
    /// ([`crate::schedule::recv_schedule_into`] /
    /// [`crate::schedule::send_schedule_into`]): Θ(p log p) time and
    /// space per rank (proportional to the `p` buffers the rank must
    /// hold anyway), **independently computed, no communication, no
    /// shared [`RowTable`]** — exactly the paper's per-processor
    /// discipline.
    pub fn build_rank_local(sk: &Arc<Skips>, n: usize) -> Arc<Self> {
        assert!(n > 0);
        let p = sk.p();
        let q = sk.q();
        let x = if q == 0 { 0 } else { (q - (n - 1) % q) % q };
        let mut arena = vec![0i8; p * 2 * q];
        if q > 0 {
            let mut rbuf = vec![0i64; q];
            let mut sbuf = vec![0i64; q];
            for rel in 0..p {
                let bb = crate::schedule::recv_schedule_into(sk, rel, &mut rbuf);
                crate::schedule::send_schedule_into(sk, rel, bb, &mut sbuf);
                let row = &mut arena[rel * 2 * q..(rel + 1) * 2 * q];
                for (dst, &v) in row[..q].iter_mut().zip(rbuf.iter()) {
                    *dst = v as i8;
                }
                for (dst, &v) in row[q..].iter_mut().zip(sbuf.iter()) {
                    *dst = v as i8;
                }
            }
        }
        Arc::new(ScheduleTable {
            sk: sk.clone(),
            rows: Rows::Local { arena, q },
            n,
            x,
        })
    }

    /// The shared all-ranks row table backing this view, when there is
    /// one (`None` for rank-local SPMD tables).
    #[inline]
    pub fn shared_rows(&self) -> Option<&Arc<RowTable>> {
        match &self.rows {
            Rows::Shared(t) => Some(t),
            Rows::Local { .. } => None,
        }
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.sk.p()
    }

    #[inline]
    pub fn q(&self) -> usize {
        self.sk.q()
    }

    /// Total rounds `n - 1 + q`.
    #[inline]
    pub fn rounds(&self) -> usize {
        if self.p() == 1 {
            0
        } else {
            self.n - 1 + self.q()
        }
    }

    /// Round slot `k` for network round `j`.
    #[inline]
    pub fn slot(&self, j: usize) -> usize {
        (j + self.x) % self.q()
    }

    /// Phase-advanced schedule value at network round `j` for relative
    /// rank `rel`: `recv` or `send` entry per `which`.
    #[inline]
    fn value_at(&self, rel: usize, j: usize, recv: bool) -> i64 {
        let (k, delta) = self.round_params(j);
        let base =
            if recv { self.rows.recv_raw(rel, k) } else { self.rows.send_raw(rel, k) };
        base as i64 + delta
    }

    /// Receive-block value of relative rank `rel` at network round `j`.
    #[inline]
    pub fn recv_at(&self, rel: usize, j: usize) -> i64 {
        self.value_at(rel, j, true)
    }

    /// Send-block value of relative rank `rel` at network round `j`.
    #[inline]
    pub fn send_at(&self, rel: usize, j: usize) -> i64 {
        self.value_at(rel, j, false)
    }

    /// Per-round constants `(k, delta)` such that the phase-advanced
    /// value for any relative rank is `rows.{recv,send}_raw(rel, k) + delta`
    /// — hoists the round arithmetic out of the per-root packing loops
    /// (which visit up to `p` roots per rank per round). One shared
    /// definition with the sparse engine
    /// ([`super::common::phase_params`]).
    #[inline]
    pub fn round_params(&self, j: usize) -> (usize, i64) {
        super::common::phase_params(self.q(), self.x, j)
    }

    /// `recv` entry of `rel` given hoisted round params.
    #[inline]
    pub fn recv_fast(&self, rel: usize, k: usize, delta: i64) -> i64 {
        self.rows.recv_raw(rel, k) as i64 + delta
    }

    /// `send` entry of `rel` given hoisted round params.
    #[inline]
    pub fn send_fast(&self, rel: usize, k: usize, delta: i64) -> i64 {
        self.rows.send_raw(rel, k) as i64 + delta
    }

    /// Cap a block value to `None` / `Some(block index)`.
    #[inline]
    pub fn cap(&self, v: i64) -> Option<usize> {
        if v < 0 {
            None
        } else if v as usize >= self.n {
            Some(self.n - 1)
        } else {
            Some(v as usize)
        }
    }
}

/// Per-rank state machine for Algorithm 7.
///
/// Buffers are stored *flat* per root (one `Vec<T>` per root, block
/// geometry mapping blocks to ranges) with a receive bitmap — `O(p·n)`
/// bits of bookkeeping instead of `O(p·n)` separate allocations, which is
/// what makes the Fig. 2 scale (p = 1152) tractable.
pub struct AllgathervProc<T> {
    pub rank: usize,
    table: Arc<ScheduleTable>,
    /// Element counts per root (kept for introspection).
    pub counts: Arc<Vec<usize>>,
    /// Geometry per root (counts[j] split into n blocks).
    geoms: Vec<BlockGeometry>,
    /// `bufs[j]`: root `j`'s data, filled in block by block.
    bufs: Vec<Vec<T>>,
    /// Bit `j*n + b`: block `b` of root `j` has been received.
    received: Vec<u64>,
    /// Roots with a non-zero contribution, in increasing order — the only
    /// ones pack/unpack ever touch (the paper's "entirely skipped" rule;
    /// this is what keeps the degenerate distribution O(1) per round
    /// instead of O(p)).
    nonempty: Arc<Vec<usize>>,
}

impl<T: Element> AllgathervProc<T> {
    /// `own` is this rank's contribution (`counts[rank]` elements).
    pub fn new(
        table: Arc<ScheduleTable>,
        counts: Arc<Vec<usize>>,
        rank: usize,
        own: &[T],
    ) -> Self {
        let p = table.p();
        assert_eq!(counts.len(), p);
        assert_eq!(own.len(), counts[rank]);
        let n = table.n;
        let geoms: Vec<BlockGeometry> =
            counts.iter().map(|&c| BlockGeometry::new(c, n)).collect();
        let mut bufs: Vec<Vec<T>> =
            counts.iter().map(|&c| vec![T::default(); c]).collect();
        bufs[rank].copy_from_slice(own);
        let nonempty = Arc::new(
            (0..p).filter(|&j| counts[j] > 0).collect::<Vec<_>>(),
        );
        let mut proc_ = AllgathervProc {
            rank,
            table,
            counts,
            geoms,
            bufs,
            received: vec![0u64; (p * n + 63) / 64],
            nonempty,
        };
        for b in 0..n {
            proc_.mark_received(rank, b);
        }
        proc_
    }

    #[inline]
    fn has_block(&self, j: usize, b: usize) -> bool {
        let bit = j * self.table.n + b;
        self.received[bit / 64] & (1 << (bit % 64)) != 0
    }

    #[inline]
    fn mark_received(&mut self, j: usize, b: usize) {
        let bit = j * self.table.n + b;
        self.received[bit / 64] |= 1 << (bit % 64);
    }

    /// Relative rank of `self.rank` w.r.t. root `j` (branch instead of
    /// division: both operands are < p).
    #[inline]
    fn rel(&self, j: usize) -> usize {
        let t = self.rank + self.table.p() - j;
        if t >= self.table.p() {
            t - self.table.p()
        } else {
            t
        }
    }

    /// True iff this rank receives anything in round `jr` (early-exit).
    fn receives_in(&self, jr: usize) -> bool {
        let (k, delta) = self.table.round_params(jr);
        for &j in self.nonempty.iter() {
            if j == self.rank {
                continue;
            }
            if let Some(b) = self.table.cap(self.table.recv_fast(self.rel(j), k, delta)) {
                if self.geoms[j].len(b) > 0 {
                    return true;
                }
            }
        }
        false
    }

    /// Visit the (root, block, len) triples packed for the to-processor
    /// `t` in round `jr`: for each non-empty root `j != t`, the send value
    /// of our relative rank — which equals `t`'s receive value.
    fn for_each_pack(&self, jr: usize, t: usize, mut f: impl FnMut(usize, usize, usize)) {
        let (k, delta) = self.table.round_params(jr);
        for &j in self.nonempty.iter() {
            if j == t {
                continue; // t is the root of j's broadcast: already has it
            }
            if let Some(b) = self.table.cap(self.table.send_fast(self.rel(j), k, delta)) {
                let len = self.geoms[j].len(b);
                if len > 0 {
                    f(j, b, len);
                }
            }
        }
    }

    /// Reassemble all `p` buffers (must be complete).
    pub fn into_buffers(self) -> Vec<Vec<T>> {
        assert!(self.complete(), "rank {}: blocks missing", self.rank);
        self.bufs
    }

    pub fn complete(&self) -> bool {
        (0..self.table.p()).all(|j| {
            (0..self.table.n)
                .all(|b| self.geoms[j].len(b) == 0 || self.has_block(j, b))
        })
    }
}

impl<T: Element> RankProc<T> for AllgathervProc<T> {
    fn send(&mut self, jr: usize) -> Option<Msg<T>> {
        let p = self.table.p();
        let k = self.table.slot(jr);
        let to = (self.rank + self.table.sk.skip(k)) % p;
        let mut data: Vec<T> = Vec::new();
        let rank = self.rank;
        let n = self.table.n;
        let bufs = &self.bufs;
        let geoms = &self.geoms;
        let received = &self.received;
        self.for_each_pack(jr, to, |j, b, len| {
            let bit = j * n + b;
            assert!(
                received[bit / 64] & (1 << (bit % 64)) != 0,
                "rank {rank}: scheduled to pack root {j} block {b} in round {jr} \
                 but it has not been received"
            );
            let (off, _) = geoms[j].range(b);
            data.extend_from_slice(&bufs[j][off..off + len]);
        });
        if data.is_empty() {
            return None;
        }
        Some(Msg { to, data })
    }

    fn expects(&self, jr: usize) -> Option<usize> {
        if !self.receives_in(jr) {
            return None;
        }
        let p = self.table.p();
        let k = self.table.slot(jr);
        Some((self.rank + p - self.table.sk.skip(k)) % p)
    }

    fn recv(&mut self, jr: usize, _from: usize, data: Vec<T>) {
        let rank = self.rank;
        let n = self.table.n;
        let table = self.table.clone();
        let nonempty = self.nonempty.clone();
        let (k, delta) = table.round_params(jr);
        let mut off = 0usize;
        for &j in nonempty.iter() {
            if j == rank {
                continue;
            }
            let t = rank + table.p() - j;
            let rel = if t >= table.p() { t - table.p() } else { t };
            if let Some(b) = table.cap(table.recv_fast(rel, k, delta)) {
                let len = self.geoms[j].len(b);
                if len > 0 {
                    let (boff, _) = self.geoms[j].range(b);
                    self.bufs[j][boff..boff + len].copy_from_slice(&data[off..off + len]);
                    let bit = j * n + b;
                    self.received[bit / 64] |= 1 << (bit % 64);
                    off += len;
                }
            }
        }
        assert_eq!(off, data.len(), "rank {rank} round {jr}: payload size mismatch");
    }

    fn rounds(&self) -> usize {
        self.table.rounds()
    }
}

/// Build all `p` rank state machines over one shared [`ScheduleTable`] —
/// the shared construction loop used by the [`crate::comm`] backends (the
/// SPMD plane builds one machine per rank over a rank-local table instead).
pub fn build_allgatherv_procs<T: Element>(
    table: Arc<ScheduleTable>,
    counts: Arc<Vec<usize>>,
    inputs: &[Vec<T>],
) -> Vec<AllgathervProc<T>> {
    crate::comm::build_procs(table.p(), |r| {
        AllgathervProc::new(table.clone(), counts.clone(), r, &inputs[r])
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{Algo, AllgathervReq, Communicator};
    use crate::sim::cost::UnitCost;

    fn check_allgatherv(counts: &[usize], n: usize) {
        let p = counts.len();
        let inputs: Vec<Vec<i32>> = (0..p)
            .map(|r| (0..counts[r]).map(|i| (r * 10000 + i) as i32).collect())
            .collect();
        let comm = Communicator::builder(p).cost_model(UnitCost).build();
        let out = comm
            .allgatherv(AllgathervReq::new(&inputs).algo(Algo::Circulant).blocks(n))
            .unwrap();
        for r in 0..p {
            for j in 0..p {
                assert_eq!(
                    out.buffers[r][j], inputs[j],
                    "rank {r} root {j} counts={counts:?} n={n}"
                );
            }
        }
        if p > 1 {
            let q = crate::schedule::ceil_log2(p);
            assert_eq!(out.stats.rounds, n - 1 + q);
        }
    }

    #[test]
    fn allgather_regular_grid() {
        for p in 1..=16 {
            for n in [1usize, 2, 4, 7] {
                check_allgatherv(&vec![24; p], n);
            }
        }
    }

    #[test]
    fn allgatherv_irregular_mod3() {
        // The paper's "irregular" problem: rank i contributes
        // (i mod 3) * m/p elements.
        for p in [7usize, 9, 12, 17] {
            let base = 15;
            let counts: Vec<usize> = (0..p).map(|i| (i % 3) * base).collect();
            for n in [1usize, 3, 5] {
                check_allgatherv(&counts, n);
            }
        }
    }

    #[test]
    fn allgatherv_degenerate() {
        // The paper's "degenerate" problem: one rank has everything.
        for p in [5usize, 9, 17] {
            for owner in [0usize, 1, p - 1] {
                let mut counts = vec![0usize; p];
                counts[owner] = 120;
                for n in [1usize, 4, 9] {
                    check_allgatherv(&counts, n);
                }
            }
        }
    }

    #[test]
    fn allgatherv_wild_counts() {
        check_allgatherv(&[3, 0, 17, 1, 0, 0, 64, 2, 9], 4);
        check_allgatherv(&[1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1], 2);
        check_allgatherv(&[100, 1], 5);
    }

    #[test]
    fn allgatherv_paper_17(){
        let counts: Vec<usize> = (0..17).map(|i| (i * 13) % 40).collect();
        for n in [1usize, 2, 5, 10] {
            check_allgatherv(&counts, n);
        }
    }

    #[test]
    fn rank_local_table_matches_shared_rows() {
        // The SPMD plane's rank-locally computed rows must be
        // bit-identical to the shared god-view plane for every relative
        // rank and round (they are the same schedules, computed by the
        // same cores — only provenance differs).
        for p in [1usize, 2, 9, 17, 18, 33] {
            let sk = Arc::new(Skips::new(p));
            for n in [1usize, 3, 7] {
                let shared = ScheduleTable::build_from(&ScheduleSource::Direct(&sk), n);
                let local = ScheduleTable::build_rank_local(&sk, n);
                assert!(local.shared_rows().is_none());
                assert!(shared.shared_rows().is_some());
                assert_eq!(local.x, shared.x, "p={p} n={n}");
                for rel in 0..p {
                    for j in 0..shared.rounds() {
                        assert_eq!(
                            local.recv_at(rel, j),
                            shared.recv_at(rel, j),
                            "recv p={p} n={n} rel={rel} j={j}"
                        );
                        assert_eq!(
                            local.send_at(rel, j),
                            shared.send_at(rel, j),
                            "send p={p} n={n} rel={rel} j={j}"
                        );
                    }
                }
            }
        }
    }
}
