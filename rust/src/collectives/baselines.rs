//! Classical baseline collectives — the "native MPI" comparators of the
//! paper's experiments (Figures 1 and 2).
//!
//! These are the algorithms an MPI library's tuned module actually picks
//! from: binomial-tree broadcast/reduce (latency-optimal, bandwidth-poor),
//! van de Geijn scatter+ring-allgather broadcast (bandwidth 2mβ), and
//! ring all-gather(v)/reduce-scatter (bandwidth-optimal, latency `p-1`
//! rounds). All run on the same simulator and cost models as the
//! circulant-schedule collectives, so the comparisons isolate algorithm
//! structure.

use std::sync::Arc;

use crate::schedule::{ceil_log2, OptTree};
use crate::sim::network::{Msg, RankProc};

use super::common::{BlockGeometry, Element, ReduceOp};

// ---------------------------------------------------------------------
// Binomial-tree broadcast
// ---------------------------------------------------------------------

/// Binomial-tree broadcast: `q` rounds, the full `m`-element buffer on
/// every edge. Latency-optimal for `n = 1`; the classical small-message
/// `MPI_Bcast` algorithm.
pub struct BinomialBcastProc<T> {
    rank: usize,
    root: usize,
    p: usize,
    q: usize,
    buf: Option<Vec<T>>,
}

impl<T: Element> BinomialBcastProc<T> {
    pub fn new(p: usize, rank: usize, root: usize, data: Option<&[T]>) -> Self {
        let q = ceil_log2(p);
        BinomialBcastProc { rank, root, p, q, buf: data.map(|d| d.to_vec()) }
    }

    #[inline]
    fn vrel(&self) -> usize {
        (self.rank + self.p - self.root % self.p) % self.p
    }

    pub fn into_buffer(self) -> Vec<T> {
        self.buf.unwrap_or_else(|| panic!("rank {}: never received", self.rank))
    }
}

impl<T: Element> RankProc<T> for BinomialBcastProc<T> {
    fn send(&mut self, t: usize) -> Option<Msg<T>> {
        let v = self.vrel();
        // Round t: every rank v < 2^t sends to v + 2^t (if it exists).
        if v < (1usize << t) && v + (1 << t) < self.p {
            let to = (self.rank + (1 << t)) % self.p;
            let data = self.buf.as_ref().expect("binomial: sending before receiving").clone();
            Some(Msg { to, data })
        } else {
            None
        }
    }

    fn expects(&self, t: usize) -> Option<usize> {
        let v = self.vrel();
        if v >= (1 << t) && v < (1 << (t + 1)) {
            Some((self.rank + self.p - (1 << t)) % self.p)
        } else {
            None
        }
    }

    fn recv(&mut self, _t: usize, _from: usize, data: Vec<T>) {
        self.buf = Some(data);
    }

    fn rounds(&self) -> usize {
        if self.p == 1 {
            0
        } else {
            self.q
        }
    }
}

// ---------------------------------------------------------------------
// Binomial-tree reduction
// ---------------------------------------------------------------------

/// Binomial-tree reduction: the reversed binomial broadcast, full vector
/// per edge, combine at each parent. The classical `MPI_Reduce`.
pub struct BinomialReduceProc<T> {
    rank: usize,
    root: usize,
    p: usize,
    q: usize,
    op: Arc<dyn ReduceOp<T>>,
    buf: Vec<T>,
}

impl<T: Element> BinomialReduceProc<T> {
    pub fn new(p: usize, rank: usize, root: usize, data: &[T], op: Arc<dyn ReduceOp<T>>) -> Self {
        BinomialReduceProc { rank, root, p, q: ceil_log2(p), op, buf: data.to_vec() }
    }

    #[inline]
    fn vrel(&self) -> usize {
        (self.rank + self.p - self.root % self.p) % self.p
    }

    /// Mirrored binomial round for network round `j`.
    #[inline]
    fn t(&self, j: usize) -> usize {
        self.q - 1 - j
    }

    pub fn into_buffer(self) -> Vec<T> {
        self.buf
    }
}

impl<T: Element> RankProc<T> for BinomialReduceProc<T> {
    fn send(&mut self, j: usize) -> Option<Msg<T>> {
        let t = self.t(j);
        let v = self.vrel();
        if v >= (1 << t) && v < (1 << (t + 1)) {
            let to = (self.rank + self.p - (1 << t)) % self.p;
            Some(Msg { to, data: self.buf.clone() })
        } else {
            None
        }
    }

    fn expects(&self, j: usize) -> Option<usize> {
        let t = self.t(j);
        let v = self.vrel();
        if v < (1 << t) && v + (1 << t) < self.p {
            Some((self.rank + (1 << t)) % self.p)
        } else {
            None
        }
    }

    fn recv(&mut self, _j: usize, _from: usize, data: Vec<T>) {
        self.op.combine(&mut self.buf, &data);
    }

    fn rounds(&self) -> usize {
        if self.p == 1 {
            0
        } else {
            self.q
        }
    }
}

// ---------------------------------------------------------------------
// Karp optimal-tree broadcast / reduction (the cost plane's baseline)
// ---------------------------------------------------------------------

/// LogP-optimal tree broadcast: the full `m`-element buffer on every
/// edge of a shared [`OptTree`] (built once for the run's machine
/// parameters — see [`crate::schedule::opttree`]). Tree node `v` is
/// root-relative rank `v`, so any root runs the same tree shape.
pub struct OptTreeBcastProc<T> {
    rank: usize,
    root: usize,
    p: usize,
    tree: Arc<OptTree>,
    buf: Option<Vec<T>>,
}

impl<T: Element> OptTreeBcastProc<T> {
    pub fn new(tree: Arc<OptTree>, p: usize, rank: usize, root: usize, data: Option<&[T]>) -> Self {
        assert_eq!(tree.p(), p, "tree built for a different world size");
        OptTreeBcastProc { rank, root, p, tree, buf: data.map(|d| d.to_vec()) }
    }

    #[inline]
    fn vrel(&self) -> usize {
        (self.rank + self.p - self.root % self.p) % self.p
    }

    #[inline]
    fn abs(&self, node: usize) -> usize {
        (node + self.root) % self.p
    }

    pub fn into_buffer(self) -> Vec<T> {
        self.buf.unwrap_or_else(|| panic!("rank {}: never received", self.rank))
    }
}

impl<T: Element> RankProc<T> for OptTreeBcastProc<T> {
    fn send(&mut self, round: usize) -> Option<Msg<T>> {
        let child = self.tree.bcast_send(self.vrel(), round)?;
        let data = self.buf.as_ref().expect("opttree: sending before receiving").clone();
        Some(Msg { to: self.abs(child), data })
    }

    fn expects(&self, round: usize) -> Option<usize> {
        self.tree.bcast_recv(self.vrel(), round).map(|v| self.abs(v))
    }

    fn recv(&mut self, _round: usize, _from: usize, data: Vec<T>) {
        self.buf = Some(data);
    }

    fn rounds(&self) -> usize {
        self.tree.rounds()
    }
}

/// LogP-optimal tree reduction: the broadcast tree reversed
/// round-by-round — every node ⊕-combines its children's partials (they
/// all arrive strictly before its own send round by construction), then
/// forwards the accumulated vector to its parent.
pub struct OptTreeReduceProc<T> {
    rank: usize,
    root: usize,
    p: usize,
    tree: Arc<OptTree>,
    op: Arc<dyn ReduceOp<T>>,
    buf: Vec<T>,
}

impl<T: Element> OptTreeReduceProc<T> {
    pub fn new(
        tree: Arc<OptTree>,
        p: usize,
        rank: usize,
        root: usize,
        data: &[T],
        op: Arc<dyn ReduceOp<T>>,
    ) -> Self {
        assert_eq!(tree.p(), p, "tree built for a different world size");
        OptTreeReduceProc { rank, root, p, tree, op, buf: data.to_vec() }
    }

    #[inline]
    fn vrel(&self) -> usize {
        (self.rank + self.p - self.root % self.p) % self.p
    }

    #[inline]
    fn abs(&self, node: usize) -> usize {
        (node + self.root) % self.p
    }

    pub fn into_buffer(self) -> Vec<T> {
        self.buf
    }
}

impl<T: Element> RankProc<T> for OptTreeReduceProc<T> {
    fn send(&mut self, round: usize) -> Option<Msg<T>> {
        let parent = self.tree.reduce_send(self.vrel(), round)?;
        Some(Msg { to: self.abs(parent), data: self.buf.clone() })
    }

    fn expects(&self, round: usize) -> Option<usize> {
        self.tree.reduce_recv(self.vrel(), round).map(|v| self.abs(v))
    }

    fn recv(&mut self, _round: usize, _from: usize, data: Vec<T>) {
        self.op.combine(&mut self.buf, &data);
    }

    fn rounds(&self) -> usize {
        self.tree.rounds()
    }
}

// ---------------------------------------------------------------------
// van de Geijn broadcast: binomial scatter + ring all-gather
// ---------------------------------------------------------------------

/// Large-message broadcast: binomial-tree scatter of `p` chunks followed
/// by a ring all-gather — bandwidth `≈ 2mβ`, `q + p - 1` rounds. The
/// classical large-message `MPI_Bcast` (what OpenMPI's tuned module
/// selects for big buffers).
pub struct VdgBcastProc<T> {
    rank: usize,
    root: usize,
    p: usize,
    q: usize,
    geom: BlockGeometry,
    /// chunk index -> data (filled progressively).
    chunks: Vec<Option<Vec<T>>>,
}

impl<T: Element> VdgBcastProc<T> {
    pub fn new(p: usize, rank: usize, root: usize, m: usize, data: Option<&[T]>) -> Self {
        let q = ceil_log2(p);
        let geom = BlockGeometry::new(m, p);
        let chunks = if let Some(buf) = data {
            assert_eq!(buf.len(), m);
            (0..p)
                .map(|c| {
                    let (off, len) = geom.range(c);
                    Some(buf[off..off + len].to_vec())
                })
                .collect()
        } else {
            vec![None; p]
        };
        VdgBcastProc { rank, root, p, q, geom, chunks }
    }

    #[inline]
    fn vrel(&self) -> usize {
        (self.rank + self.p - self.root % self.p) % self.p
    }

    #[inline]
    fn abs(&self, vrel: usize) -> usize {
        (vrel + self.root) % self.p
    }

    /// Chunk range [lo, hi) sent from parent `v` to child `v + half` in
    /// scatter round `t` (levels of size `2^(q-t)`), clipped to `p`.
    fn scatter_edge(&self, t: usize, v: usize) -> Option<(usize, usize, usize)> {
        let level = 1usize << (self.q - t); // subtree size at this round
        let half = level >> 1;
        if half == 0 || v % level != 0 {
            return None;
        }
        let child = v + half;
        if child >= self.p {
            return None;
        }
        let hi = (v + level).min(self.p);
        Some((child, child, hi)) // (child vrel, chunk lo, chunk hi)
    }

    fn chunk_payload(&self, lo: usize, hi: usize) -> Vec<T> {
        let mut data = Vec::new();
        for c in lo..hi {
            if self.geom.len(c) == 0 {
                continue;
            }
            let blk = self.chunks[c]
                .as_ref()
                .unwrap_or_else(|| panic!("rank {}: chunk {c} missing for scatter", self.rank));
            data.extend_from_slice(blk);
        }
        data
    }

    pub fn into_buffer(self) -> Vec<T> {
        let mut out = Vec::with_capacity(self.geom.m);
        for (c, blk) in self.chunks.into_iter().enumerate() {
            if self.geom.len(c) == 0 {
                continue;
            }
            out.extend_from_slice(
                &blk.unwrap_or_else(|| panic!("rank {}: chunk {c} never arrived", self.rank)),
            );
        }
        out
    }
}

impl<T: Element> RankProc<T> for VdgBcastProc<T> {
    fn send(&mut self, round: usize) -> Option<Msg<T>> {
        let v = self.vrel();
        if round < self.q {
            // Scatter phase.
            let (child, lo, hi) = self.scatter_edge(round, v)?;
            // Only send if we already hold the range (parents do).
            let data = self.chunk_payload(lo, hi);
            if data.is_empty() {
                return None;
            }
            Some(Msg { to: self.abs(child), data })
        } else {
            // Ring phase: round u, send chunk (v - u) mod p to v+1.
            let u = round - self.q;
            let c = (v + self.p - u) % self.p;
            if self.geom.len(c) == 0 {
                return None;
            }
            let data = self.chunks[c]
                .as_ref()
                .unwrap_or_else(|| panic!("rank {}: ring chunk {c} missing", self.rank))
                .clone();
            Some(Msg { to: self.abs((v + 1) % self.p), data })
        }
    }

    fn expects(&self, round: usize) -> Option<usize> {
        let v = self.vrel();
        if round < self.q {
            let level = 1usize << (self.q - round);
            let half = level >> 1;
            if half != 0 && v % level == half {
                // We are the child of v - half this round.
                let lo = v;
                let hi = (v - half + level).min(self.p);
                let len: usize = (lo..hi).map(|c| self.geom.len(c)).sum();
                if len > 0 {
                    return Some(self.abs(v - half));
                }
            }
            None
        } else {
            let u = round - self.q;
            let prev = (v + self.p - 1) % self.p;
            let c = (prev + self.p - u) % self.p;
            if self.geom.len(c) == 0 {
                None
            } else {
                Some(self.abs(prev))
            }
        }
    }

    fn recv(&mut self, round: usize, _from: usize, data: Vec<T>) {
        let v = self.vrel();
        if round < self.q {
            let level = 1usize << (self.q - round);
            let half = level >> 1;
            debug_assert_eq!(v % level, half);
            let lo = v;
            let hi = (v - half + level).min(self.p);
            let mut off = 0usize;
            for c in lo..hi {
                let len = self.geom.len(c);
                if len == 0 {
                    continue;
                }
                self.chunks[c] = Some(data[off..off + len].to_vec());
                off += len;
            }
            debug_assert_eq!(off, data.len());
        } else {
            let u = round - self.q;
            let prev = (v + self.p - 1) % self.p;
            let c = (prev + self.p - u) % self.p;
            self.chunks[c] = Some(data);
        }
    }

    fn rounds(&self) -> usize {
        if self.p == 1 {
            0
        } else {
            self.q + self.p - 1
        }
    }
}

// ---------------------------------------------------------------------
// Ring all-gather(v)
// ---------------------------------------------------------------------

/// Ring all-gather(v): `p - 1` rounds; rank `r` forwards chunk
/// `(r - u) mod p` to `r + 1` in round `u`. Bandwidth-optimal for regular
/// inputs; for the degenerate distribution every round carries the one big
/// chunk — the pathology the paper's Fig. 2 exposes in native libraries.
pub struct RingAllgathervProc<T> {
    rank: usize,
    p: usize,
    counts: Arc<Vec<usize>>,
    chunks: Vec<Option<Vec<T>>>,
}

impl<T: Element> RingAllgathervProc<T> {
    pub fn new(p: usize, rank: usize, counts: Arc<Vec<usize>>, own: &[T]) -> Self {
        assert_eq!(own.len(), counts[rank]);
        let mut chunks = vec![None; p];
        chunks[rank] = Some(own.to_vec());
        RingAllgathervProc { rank, p, counts, chunks }
    }

    pub fn into_buffers(self) -> Vec<Vec<T>> {
        self.chunks
            .into_iter()
            .enumerate()
            .map(|(j, c)| {
                if self.counts[j] == 0 {
                    Vec::new()
                } else {
                    c.unwrap_or_else(|| panic!("rank {}: chunk {j} never arrived", self.rank))
                }
            })
            .collect()
    }
}

impl<T: Element> RankProc<T> for RingAllgathervProc<T> {
    fn send(&mut self, u: usize) -> Option<Msg<T>> {
        let c = (self.rank + self.p - u) % self.p;
        if self.counts[c] == 0 {
            return None;
        }
        let data = self.chunks[c]
            .as_ref()
            .unwrap_or_else(|| panic!("rank {}: ring chunk {c} missing in round {u}", self.rank))
            .clone();
        Some(Msg { to: (self.rank + 1) % self.p, data })
    }

    fn expects(&self, u: usize) -> Option<usize> {
        let prev = (self.rank + self.p - 1) % self.p;
        let c = (prev + self.p - u) % self.p;
        if self.counts[c] == 0 {
            None
        } else {
            Some(prev)
        }
    }

    fn recv(&mut self, u: usize, _from: usize, data: Vec<T>) {
        let prev = (self.rank + self.p - 1) % self.p;
        let c = (prev + self.p - u) % self.p;
        self.chunks[c] = Some(data);
    }

    fn rounds(&self) -> usize {
        if self.p == 1 {
            0
        } else {
            self.p - 1
        }
    }
}

// ---------------------------------------------------------------------
// Ring reduce-scatter (bucket algorithm)
// ---------------------------------------------------------------------

/// Ring reduce-scatter: `p - 1` rounds; each chunk travels the ring
/// accumulating contributions and ends at its owner. The classical
/// algorithm of [7, 18] the paper contrasts with.
pub struct RingReduceScatterProc<T> {
    rank: usize,
    p: usize,
    counts: Arc<Vec<usize>>,
    op: Arc<dyn ReduceOp<T>>,
    /// Per-destination partials (own contributions, accumulated in place).
    partial: Vec<Vec<T>>,
}

impl<T: Element> RingReduceScatterProc<T> {
    pub fn new(
        p: usize,
        rank: usize,
        counts: Arc<Vec<usize>>,
        input: &[T],
        op: Arc<dyn ReduceOp<T>>,
    ) -> Self {
        let total: usize = counts.iter().sum();
        assert_eq!(input.len(), total);
        let mut partial = Vec::with_capacity(p);
        let mut off = 0usize;
        for j in 0..p {
            partial.push(input[off..off + counts[j]].to_vec());
            off += counts[j];
        }
        RingReduceScatterProc { rank, p, counts, op, partial }
    }

    /// Chunk this rank forwards in round `u`: `(rank - 1 - u) mod p`.
    #[inline]
    fn chunk_out(&self, u: usize) -> usize {
        (self.rank + 2 * self.p - 1 - u) % self.p
    }

    pub fn into_chunk(self) -> Vec<T> {
        let r = self.rank;
        self.partial.into_iter().nth(r).unwrap()
    }
}

impl<T: Element> RankProc<T> for RingReduceScatterProc<T> {
    fn send(&mut self, u: usize) -> Option<Msg<T>> {
        let c = self.chunk_out(u);
        if self.counts[c] == 0 {
            return None;
        }
        Some(Msg { to: (self.rank + 1) % self.p, data: self.partial[c].clone() })
    }

    fn expects(&self, u: usize) -> Option<usize> {
        let prev = (self.rank + self.p - 1) % self.p;
        let c = (prev + 2 * self.p - 1 - u) % self.p;
        if self.counts[c] == 0 {
            None
        } else {
            Some(prev)
        }
    }

    fn recv(&mut self, u: usize, _from: usize, data: Vec<T>) {
        let prev = (self.rank + self.p - 1) % self.p;
        let c = (prev + 2 * self.p - 1 - u) % self.p;
        self.op.combine(&mut self.partial[c], &data);
    }

    fn rounds(&self) -> usize {
        if self.p == 1 {
            0
        } else {
            self.p - 1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::common::SumOp;
    use crate::comm::{
        Algo, AllgathervReq, BcastReq, Communicator, ReduceReq, ReduceScatterReq,
    };
    use crate::sim::cost::UnitCost;

    fn comm(p: usize) -> Communicator {
        Communicator::builder(p).cost_model(UnitCost).build()
    }

    #[test]
    fn binomial_bcast_all_p() {
        for p in 1..=33 {
            for root in [0, p / 2, p - 1] {
                let data: Vec<u32> = (0..50).collect();
                let out =
                    comm(p).bcast(BcastReq::new(root, &data).algo(Algo::Binomial)).unwrap();
                for b in &out.buffers {
                    assert_eq!(b, &data, "p={p} root={root}");
                }
                if p > 1 {
                    assert_eq!(out.stats.rounds, ceil_log2(p));
                }
            }
        }
    }

    #[test]
    fn binomial_reduce_all_p() {
        for p in 1..=33usize {
            let m = 20;
            let inputs: Vec<Vec<i64>> =
                (0..p).map(|r| (0..m).map(|i| (r + i) as i64).collect()).collect();
            let expect: Vec<i64> =
                (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
            for root in [0, p - 1] {
                let out = comm(p)
                    .reduce(ReduceReq::new(root, &inputs, Arc::new(SumOp)).algo(Algo::Binomial))
                    .unwrap();
                assert_eq!(out.buffers, expect, "p={p} root={root}");
            }
        }
    }

    #[test]
    fn opttree_bcast_all_p() {
        for p in 1..=33 {
            for root in [0, p / 2, p - 1] {
                let data: Vec<u32> = (0..50).collect();
                let out = comm(p).bcast(BcastReq::new(root, &data).algo(Algo::OptTree)).unwrap();
                for b in &out.buffers {
                    assert_eq!(b, &data, "p={p} root={root}");
                }
            }
        }
    }

    #[test]
    fn opttree_reduce_all_p() {
        for p in 1..=33usize {
            let m = 20;
            let inputs: Vec<Vec<i64>> =
                (0..p).map(|r| (0..m).map(|i| (r + i) as i64).collect()).collect();
            let expect: Vec<i64> = (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
            for root in [0, p - 1] {
                let out = comm(p)
                    .reduce(ReduceReq::new(root, &inputs, Arc::new(SumOp)).algo(Algo::OptTree))
                    .unwrap();
                assert_eq!(out.buffers, expect, "p={p} root={root}");
            }
        }
    }

    #[test]
    fn vdg_bcast_all_p() {
        for p in 1..=33 {
            for root in [0, p / 3] {
                let data: Vec<u32> = (0..97).map(|i| i * 3 + 1).collect();
                let out = comm(p)
                    .bcast(BcastReq::new(root, &data).algo(Algo::VanDeGeijn))
                    .unwrap();
                for b in &out.buffers {
                    assert_eq!(b, &data, "p={p} root={root}");
                }
                if p > 1 {
                    assert_eq!(out.stats.rounds, ceil_log2(p) + p - 1);
                }
            }
        }
    }

    #[test]
    fn vdg_bandwidth_half_of_binomial() {
        // For large m, vdG moves ~2m per rank vs binomial's ~q*m total
        // bottleneck; check total bytes: binomial = (p-1)*m, vdg < 2*m*p.
        let p = 16;
        let data: Vec<u32> = (0..4096).collect();
        let b_stats =
            comm(p).bcast(BcastReq::new(0, &data).algo(Algo::Binomial)).unwrap().stats;
        let v_stats =
            comm(p).bcast(BcastReq::new(0, &data).algo(Algo::VanDeGeijn)).unwrap().stats;
        assert_eq!(b_stats.bytes, (p - 1) * 4096 * 4);
        assert!(v_stats.bytes < 2 * 4096 * 4 * p);
        // The real win: max bytes through any single rank.
        assert!(v_stats.max_rank_bytes < b_stats.max_rank_bytes);
    }

    #[test]
    fn ring_allgatherv_regular_and_irregular() {
        for p in [2usize, 5, 9, 16] {
            for style in 0..3 {
                let counts: Vec<usize> = (0..p)
                    .map(|i| match style {
                        0 => 12,
                        1 => (i % 3) * 6,
                        _ => {
                            if i == 0 {
                                48
                            } else {
                                0
                            }
                        }
                    })
                    .collect();
                let inputs: Vec<Vec<i32>> = (0..p)
                    .map(|r| (0..counts[r]).map(|i| (r * 100 + i) as i32).collect())
                    .collect();
                let out =
                    comm(p).allgatherv(AllgathervReq::new(&inputs).algo(Algo::Ring)).unwrap();
                for r in 0..p {
                    for j in 0..p {
                        assert_eq!(
                            out.buffers[r][j], inputs[j],
                            "p={p} style={style} r={r} j={j}"
                        );
                    }
                }
                if p > 1 {
                    assert_eq!(out.stats.rounds, p - 1);
                }
            }
        }
    }

    #[test]
    fn ring_reduce_scatter_correct() {
        for p in [2usize, 5, 9, 16] {
            let counts: Vec<usize> = (0..p).map(|i| 4 + (i % 3)).collect();
            let total: usize = counts.iter().sum();
            let inputs: Vec<Vec<i64>> = (0..p)
                .map(|r| (0..total).map(|i| ((r + 1) * (i + 3)) as i64).collect())
                .collect();
            let sums: Vec<i64> =
                (0..total).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
            let out = comm(p)
                .reduce_scatter(
                    ReduceScatterReq::new(&inputs, &counts, Arc::new(SumOp)).algo(Algo::Ring),
                )
                .unwrap();
            let mut off = 0;
            for r in 0..p {
                assert_eq!(
                    out.buffers[r],
                    sums[off..off + counts[r]].to_vec(),
                    "p={p} r={r}"
                );
                off += counts[r];
            }
        }
    }
}
