//! Shared vocabulary of the collectives: element types, reduction
//! operators, block geometry, and the phase-advanced schedule view used by
//! Algorithm 1 / Algorithm 7.

use std::sync::Arc;

use crate::schedule::{Schedule, ScheduleCache, ScheduleTable, Skips};

/// Data element moved by the collectives.
pub trait Element:
    Copy + Default + std::fmt::Debug + PartialEq + Send + Sync + 'static
{
}

impl<T> Element for T where
    T: Copy + Default + std::fmt::Debug + PartialEq + Send + Sync + 'static
{
}

/// A binary, associative, commutative reduction operator applied to whole
/// blocks (the paper's reduction collectives require commutativity).
pub trait ReduceOp<T>: Send + Sync {
    /// `acc[i] = acc[i] ⊕ incoming[i]` for all `i`.
    fn combine(&self, acc: &mut [T], incoming: &[T]);

    fn name(&self) -> &str {
        "op"
    }
}

/// Element-wise sum.
#[derive(Debug, Clone, Copy, Default)]
pub struct SumOp;

macro_rules! impl_sum {
    ($($t:ty),*) => {$(
        impl ReduceOp<$t> for SumOp {
            #[inline]
            fn combine(&self, acc: &mut [$t], incoming: &[$t]) {
                debug_assert_eq!(acc.len(), incoming.len());
                for (a, b) in acc.iter_mut().zip(incoming) {
                    *a += *b;
                }
            }
            fn name(&self) -> &str { "sum" }
        }
    )*};
}

impl_sum!(i32, i64, u32, u64, f32, f64);

/// Element-wise max.
#[derive(Debug, Clone, Copy, Default)]
pub struct MaxOp;

macro_rules! impl_max {
    ($($t:ty),*) => {$(
        impl ReduceOp<$t> for MaxOp {
            #[inline]
            fn combine(&self, acc: &mut [$t], incoming: &[$t]) {
                for (a, b) in acc.iter_mut().zip(incoming) {
                    if *b > *a { *a = *b; }
                }
            }
            fn name(&self) -> &str { "max" }
        }
    )*};
}

impl_max!(i32, i64, u32, u64, f32, f64);

/// Geometry of an `m`-element buffer divided into `n` roughly equal
/// blocks: the first `m % n` blocks have `ceil(m/n)` elements, the rest
/// `floor(m/n)` (MPI-style splitting; blocks of a zero-sized buffer are
/// all empty).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockGeometry {
    pub m: usize,
    pub n: usize,
}

impl BlockGeometry {
    pub fn new(m: usize, n: usize) -> Self {
        assert!(n > 0);
        BlockGeometry { m, n }
    }

    /// (offset, len) of block `b`.
    #[inline]
    pub fn range(&self, b: usize) -> (usize, usize) {
        debug_assert!(b < self.n);
        let base = self.m / self.n;
        let rem = self.m % self.n;
        if b < rem {
            (b * (base + 1), base + 1)
        } else {
            (rem * (base + 1) + (b - rem) * base, base)
        }
    }

    #[inline]
    pub fn len(&self, b: usize) -> usize {
        self.range(b).1
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.m == 0
    }
}

/// A processor's schedules in the root-relative frame, pre-shifted by the
/// `x` virtual rounds of Algorithm 1, with O(1) *stateless* per-round
/// block queries (instead of the paper's in-place `+= q` updates, so that
/// `send`/`expects` need no mutation and replay is trivial).
///
/// Algorithm 1 initialises `block[k] -= x`, then `+= q` for the `k < x`
/// virtual rounds, and `+= q` after every use. Equivalently, the value
/// used at absolute round `i` (with `i` in `x .. x + n-1+q`, `k = i mod
/// q`) is `block[k] - x + q * ceil((i - k) / q)`... concretely: the first
/// real use of slot `k` is at `i0 = k` if `k >= x` else `k + q`, and the
/// value at round `i` is `shifted[k] + q * ((i - i0) / q)` where
/// `shifted[k]` embeds the initial loop.
#[derive(Debug, Clone)]
pub struct PhasedSchedule {
    pub p: usize,
    pub q: usize,
    /// Relative rank of this processor ((rank - root) mod p).
    pub rel: usize,
    /// Number of data blocks `n`.
    pub n: usize,
    /// Virtual-round offset `x = (q - (n-1) mod q) mod q`.
    pub x: usize,
    /// The circulant graph's skip table.
    pub skips: Arc<Skips>,
    recv_shifted: Vec<i64>,
    send_shifted: Vec<i64>,
}

impl PhasedSchedule {
    /// Build from a computed [`Schedule`] for `n` blocks.
    pub fn new(skips: Arc<Skips>, sched: &Schedule, n: usize) -> Self {
        assert_eq!(skips.p(), sched.p);
        let recv = sched.recv.iter().copied();
        let send = sched.send.iter().copied();
        Self::build(skips, sched.rank, n, recv, send)
    }

    /// Build directly from one rank's raw rows of an all-ranks
    /// [`ScheduleTable`] — no intermediate [`Schedule`] allocation; this
    /// is how the table-served proc builders phase their schedules.
    pub fn from_rows(skips: Arc<Skips>, rel: usize, recv: &[i8], send: &[i8], n: usize) -> Self {
        let recv = recv.iter().map(|&v| v as i64);
        let send = send.iter().map(|&v| v as i64);
        Self::build(skips, rel, n, recv, send)
    }

    /// Build from this rank's **own** raw schedule rows as filled by the
    /// per-rank cores ([`crate::schedule::recv_schedule_into`] /
    /// [`crate::schedule::send_schedule_into`]) — the O(log p) rank-local
    /// entry point of the SPMD plane ([`crate::comm::RankComm`]): no
    /// table, no other rank's rows, just the `2q` entries this processor
    /// computed for itself.
    pub fn from_own_rows(
        skips: Arc<Skips>,
        rel: usize,
        recv: &[i64],
        send: &[i64],
        n: usize,
    ) -> Self {
        let q = skips.q();
        Self::build(skips, rel, n, recv[..q].iter().copied(), send[..q].iter().copied())
    }

    fn build(
        skips: Arc<Skips>,
        rel: usize,
        n: usize,
        recv: impl Iterator<Item = i64>,
        send: impl Iterator<Item = i64>,
    ) -> Self {
        assert!(n > 0);
        let q = skips.q();
        let x = if q == 0 { 0 } else { (q - (n - 1) % q) % q };
        let shift = |v: i64, k: usize| {
            let mut v = v - x as i64;
            if k < x {
                v += q as i64;
            }
            v
        };
        PhasedSchedule {
            p: skips.p(),
            q,
            rel,
            n,
            x,
            recv_shifted: recv.enumerate().map(|(k, v)| shift(v, k)).collect(),
            send_shifted: send.enumerate().map(|(k, v)| shift(v, k)).collect(),
            skips,
        }
    }

    /// `skip[k]`.
    #[inline]
    pub fn skip(&self, k: usize) -> usize {
        self.skips.skip(k)
    }

    /// Total communication rounds: `n - 1 + q`.
    #[inline]
    pub fn rounds(&self) -> usize {
        if self.p == 1 {
            0
        } else {
            self.n - 1 + self.q
        }
    }

    /// Absolute round `i` for network round `j` (`i = j + x`).
    #[inline]
    fn abs_round(&self, j: usize) -> usize {
        j + self.x
    }

    #[inline]
    fn phased(&self, shifted: &[i64], j: usize) -> i64 {
        let i = self.abs_round(j);
        let k = i % self.q;
        let i0 = if k >= self.x { k } else { k + self.q };
        debug_assert!(i >= i0);
        shifted[k] + (self.q * ((i - i0) / self.q)) as i64
    }

    /// The (uncapped) receive block index for network round `j`.
    #[inline]
    pub fn recv_at(&self, j: usize) -> i64 {
        self.phased(&self.recv_shifted, j)
    }

    /// The (uncapped) send block index for network round `j`.
    #[inline]
    pub fn send_at(&self, j: usize) -> i64 {
        self.phased(&self.send_shifted, j)
    }

    /// Cap a block index per Algorithm 1: negative means "no block",
    /// `>= n` means block `n - 1`.
    #[inline]
    pub fn cap(&self, v: i64) -> Option<usize> {
        if v < 0 {
            None
        } else if v as usize >= self.n {
            Some(self.n - 1)
        } else {
            Some(v as usize)
        }
    }

    /// Round-slot index `k = (j + x) mod q` for network round `j`.
    #[inline]
    pub fn slot(&self, j: usize) -> usize {
        self.abs_round(j) % self.q
    }
}

/// Compute the [`PhasedSchedule`] of `rank` for a broadcast rooted at
/// `root` over `p` processors with `n` blocks (the direct, uncached
/// path; see [`ScheduleSource`] for the one shared implementation).
pub fn phased_for(sk: &Arc<Skips>, rank: usize, root: usize, n: usize) -> PhasedSchedule {
    ScheduleSource::Direct(sk).phased(rank, root, n)
}

/// The rank-independent phase constants of Algorithm 1 for network round
/// `j` under virtual-round offset `x`: the slot `k` and the shift `delta`
/// such that the phased value of any rank's raw schedule entry is
/// `row[k] + delta` (see [`PhasedSchedule`] for the derivation; that type
/// keeps its own pre-shifted representation, which the
/// `phased_matches_paper_inplace_updates` test pins to this formula).
/// The one definition shared by the Algorithm-7 `ScheduleTable` and the
/// sparse [`crate::sim::engine`] — requires `q > 0`.
#[inline]
pub fn phase_params(q: usize, x: usize, j: usize) -> (usize, i64) {
    let i = j + x;
    let k = i % q;
    let mut delta = -(x as i64);
    if k < x {
        delta += q as i64;
    }
    let i0 = if k >= x { k } else { k + q };
    delta += (q * ((i - i0) / q)) as i64;
    (k, delta)
}

/// Where per-rank schedules come from when constructing a collective's
/// state machines: an already-built all-ranks [`ScheduleTable`] (the
/// [`crate::comm::Communicator`] path — one parallel-built flat arena
/// per `p` serves every rank, root and collective), a shared
/// [`ScheduleCache`] (compute-the-table-on-miss), or computed directly
/// per rank (throwaway, the legacy `*_sim` path).
pub enum ScheduleSource<'a> {
    /// Compute schedules on the spot from the skip table.
    Direct(&'a Arc<Skips>),
    /// Serve schedules from a shared cache (compute-on-miss).
    Cached { cache: &'a ScheduleCache, sk: &'a Arc<Skips> },
    /// Serve rows from an already-built all-ranks schedule table.
    Table(Arc<ScheduleTable>),
}

impl ScheduleSource<'_> {
    #[inline]
    pub fn skips(&self) -> &Arc<Skips> {
        match self {
            ScheduleSource::Direct(sk) => sk,
            ScheduleSource::Cached { sk, .. } => sk,
            ScheduleSource::Table(t) => t.skips(),
        }
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.skips().p()
    }

    /// The all-ranks [`ScheduleTable`] this source describes: the shared
    /// `Arc` itself on the `Table` path, the cache's per-`p` table on the
    /// `Cached` path (built in parallel on miss, with the cache's
    /// hit/miss receipts), a freshly built one on the `Direct` path.
    pub fn rows(&self) -> Arc<ScheduleTable> {
        match self {
            ScheduleSource::Direct(sk) => Arc::new(ScheduleTable::build(sk)),
            ScheduleSource::Cached { cache, sk } => cache.table(sk),
            ScheduleSource::Table(t) => t.clone(),
        }
    }

    /// The combined schedule of relative rank `rel` (owned; two
    /// `q`-element vectors on every path, so the copy is O(log p)).
    pub fn schedule(&self, rel: usize) -> Schedule {
        match self {
            ScheduleSource::Direct(sk) => Schedule::compute(sk, rel),
            ScheduleSource::Cached { cache, sk } => (*cache.get(sk.p(), rel)).clone(),
            ScheduleSource::Table(t) => t.schedule(rel),
        }
    }

    /// The [`PhasedSchedule`] of absolute `rank` for a collective rooted
    /// at `root` with `n` blocks. On the `Table` path this phases the
    /// flat rows directly ([`PhasedSchedule::from_rows`]) — no
    /// intermediate per-rank `Schedule` is materialised.
    pub fn phased(&self, rank: usize, root: usize, n: usize) -> PhasedSchedule {
        let sk = self.skips();
        let p = sk.p();
        let rel = (rank + p - root % p) % p;
        match self {
            ScheduleSource::Table(t) => {
                PhasedSchedule::from_rows(sk.clone(), rel, t.recv_row(rel), t.send_row(rel), n)
            }
            _ => {
                let sched = self.schedule(rel);
                PhasedSchedule::new(sk.clone(), &sched, n)
            }
        }
    }

    /// Fill `recv_out[0..q]` / `send_out[0..q]` with relative rank `rel`'s
    /// raw schedule rows; returns the baseblock. On the `Table` path a
    /// widening copy out of the flat arena; on the `Direct` path the
    /// stack-array cores ([`crate::schedule::recv_schedule_into`] /
    /// [`crate::schedule::send_schedule_into`]) with **zero** heap
    /// allocation per rank; on the `Cached` path a copy of the shared
    /// per-rank entry (computed on miss).
    pub fn schedule_rows_into(
        &self,
        rel: usize,
        recv_out: &mut [i64],
        send_out: &mut [i64],
    ) -> usize {
        match self {
            ScheduleSource::Direct(sk) => {
                let bb = crate::schedule::recv_schedule_into(sk, rel, recv_out);
                crate::schedule::send_schedule_into(sk, rel, bb, send_out);
                bb
            }
            ScheduleSource::Cached { cache, sk } => {
                let s = cache.get(sk.p(), rel);
                let q = sk.q();
                recv_out[..q].copy_from_slice(&s.recv);
                send_out[..q].copy_from_slice(&s.send);
                s.baseblock
            }
            ScheduleSource::Table(t) => {
                let q = t.q();
                for (dst, &v) in recv_out[..q].iter_mut().zip(t.recv_row(rel)) {
                    *dst = v as i64;
                }
                for (dst, &v) in send_out[..q].iter_mut().zip(t.send_row(rel)) {
                    *dst = v as i64;
                }
                t.baseblock(rel)
            }
        }
    }
}

/// Shared, cheaply clonable context for building all ranks of a collective.
#[derive(Clone)]
pub struct World {
    pub sk: Arc<Skips>,
}

impl World {
    pub fn new(p: usize) -> Self {
        World { sk: Arc::new(Skips::new(p)) }
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.sk.p()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_geometry_partitions() {
        for m in [0usize, 1, 7, 100, 101] {
            for n in [1usize, 2, 3, 7, 16] {
                let g = BlockGeometry::new(m, n);
                let mut covered = 0usize;
                for b in 0..n {
                    let (off, len) = g.range(b);
                    assert_eq!(off, covered, "m={m} n={n} b={b}");
                    covered += len;
                }
                assert_eq!(covered, m);
                // Roughly equal: sizes differ by at most one.
                let lens: Vec<_> = (0..n).map(|b| g.len(b)).collect();
                let mx = *lens.iter().max().unwrap();
                let mn = *lens.iter().min().unwrap();
                assert!(mx - mn <= 1, "m={m} n={n}");
            }
        }
    }

    #[test]
    fn phased_matches_paper_inplace_updates() {
        // Replay the paper's mutable bookkeeping and compare with the
        // stateless queries, for several (p, n).
        for p in [2usize, 9, 17, 18, 33] {
            let sk = Skips::new(p);
            for n in [1usize, 2, 5, 7, 12] {
                let skarc = Arc::new(sk.clone());
                for r in 0..p {
                    let sched = Schedule::compute(&sk, r);
                    let ps = PhasedSchedule::new(skarc.clone(), &sched, n);
                    let q = sched.q;
                    let x = ps.x;
                    // Paper's in-place arrays.
                    let mut recv = sched.recv.clone();
                    let mut send = sched.send.clone();
                    for k in 0..q {
                        recv[k] -= x as i64;
                        send[k] -= x as i64;
                        if k < x {
                            recv[k] += q as i64;
                            send[k] += q as i64;
                        }
                    }
                    for i in x..(n + q - 1 + x) {
                        let k = i % q;
                        let j = i - x; // network round
                        assert_eq!(ps.recv_at(j), recv[k], "p={p} n={n} r={r} i={i}");
                        assert_eq!(ps.send_at(j), send[k], "p={p} n={n} r={r} i={i}");
                        recv[k] += q as i64;
                        send[k] += q as i64;
                    }
                }
            }
        }
    }

    #[test]
    fn schedule_rows_into_matches_compute_on_all_paths() {
        for p in [1usize, 2, 9, 17, 18, 33, 100] {
            let sk = Arc::new(Skips::new(p));
            let q = sk.q();
            let cache = ScheduleCache::new();
            let direct = ScheduleSource::Direct(&sk);
            let cached = ScheduleSource::Cached { cache: &cache, sk: &sk };
            let table = ScheduleSource::Table(Arc::new(ScheduleTable::build(&sk)));
            let mut rbuf = vec![0i64; q];
            let mut sbuf = vec![0i64; q];
            for rel in 0..p {
                let want = Schedule::compute(&sk, rel);
                for src in [&direct, &cached, &table] {
                    let bb = src.schedule_rows_into(rel, &mut rbuf, &mut sbuf);
                    assert_eq!(bb, want.baseblock, "p={p} rel={rel}");
                    assert_eq!(rbuf, want.recv, "p={p} rel={rel}");
                    assert_eq!(sbuf, want.send, "p={p} rel={rel}");
                    assert_eq!(src.schedule(rel), want, "p={p} rel={rel}");
                }
            }
        }
    }

    #[test]
    fn phased_from_rows_matches_phased_from_schedule() {
        for p in [2usize, 9, 17, 33] {
            let sk = Arc::new(Skips::new(p));
            let table = Arc::new(ScheduleTable::build(&sk));
            let tsrc = ScheduleSource::Table(table.clone());
            let dsrc = ScheduleSource::Direct(&sk);
            for n in [1usize, 3, 7] {
                for root in [0, p - 1] {
                    for rank in 0..p {
                        let a = tsrc.phased(rank, root, n);
                        let b = dsrc.phased(rank, root, n);
                        assert_eq!(a.rel, b.rel, "p={p} n={n} root={root} rank={rank}");
                        for j in 0..b.rounds() {
                            assert_eq!(a.recv_at(j), b.recv_at(j), "recv p={p} j={j}");
                            assert_eq!(a.send_at(j), b.send_at(j), "send p={p} j={j}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn sum_and_max_ops() {
        let mut a = vec![1i64, 2, 3];
        SumOp.combine(&mut a, &[10, 20, 30]);
        assert_eq!(a, vec![11, 22, 33]);
        let mut b = vec![5i32, 1, 9];
        MaxOp.combine(&mut b, &[3, 7, 2]);
        assert_eq!(b, vec![5, 7, 9]);
    }
}
