//! All-reduction (`MPI_Reduce_scatter` / `MPI_Reduce_scatter_block`) —
//! Observation 1.4 of the paper: `p` simultaneous reversed-schedule
//! reductions, one per destination rank, on the circulant pattern, in the
//! optimal `n - 1 + q` rounds.
//!
//! This reverses Algorithm 7 the same way rooted reduction reverses
//! Algorithm 1: network round `jr` mirrors all-broadcast round
//! `total-1-jr` with all edges reversed; each rank accumulates incoming
//! partials with ⊕ into its per-destination blocks and ends holding the
//! fully reduced chunk for *itself*. Total volume is the optimal `p - 1`
//! blocks sent and received per rank (for `n = 1` the paper believes this
//! is the first logarithmic-round algorithm for arbitrary `p`).

use std::sync::Arc;

use crate::sim::network::{Msg, RankProc};

use super::allgatherv::ScheduleTable;
use super::common::{BlockGeometry, Element, ReduceOp};

/// Per-rank state machine for the reversed all-broadcast.
pub struct ReduceScatterProc<T> {
    pub rank: usize,
    table: Arc<ScheduleTable>,
    /// Element counts per destination (kept for introspection).
    pub counts: Arc<Vec<usize>>,
    geoms: Vec<BlockGeometry>,
    op: Arc<dyn ReduceOp<T>>,
    /// `partial[j]`: this rank's current partial of destination `j`'s
    /// chunk, flat (block geometry maps blocks to ranges; starts as our
    /// own contribution).
    partial: Vec<Vec<T>>,
    /// Destinations with non-zero chunks — the only ones ever packed.
    nonempty: Arc<Vec<usize>>,
}

impl<T: Element> ReduceScatterProc<T> {
    /// `input` is this rank's full contribution vector: the concatenation
    /// over destinations `j` of `counts[j]` elements.
    pub fn new(
        table: Arc<ScheduleTable>,
        counts: Arc<Vec<usize>>,
        rank: usize,
        input: &[T],
        op: Arc<dyn ReduceOp<T>>,
    ) -> Self {
        let p = table.p();
        assert_eq!(counts.len(), p);
        let total: usize = counts.iter().sum();
        assert_eq!(input.len(), total);
        let n = table.n;
        let geoms: Vec<BlockGeometry> =
            counts.iter().map(|&c| BlockGeometry::new(c, n)).collect();
        let mut partial = Vec::with_capacity(p);
        let mut off = 0usize;
        for j in 0..p {
            partial.push(input[off..off + counts[j]].to_vec());
            off += counts[j];
        }
        let _ = n;
        let nonempty = Arc::new((0..p).filter(|&j| counts[j] > 0).collect::<Vec<_>>());
        ReduceScatterProc { rank, table, counts, geoms, op, partial, nonempty }
    }

    #[inline]
    fn rel(&self, j: usize) -> usize {
        let t = self.rank + self.table.p() - j;
        if t >= self.table.p() {
            t - self.table.p()
        } else {
            t
        }
    }

    /// All-broadcast round mirrored by network round `jr`.
    #[inline]
    fn fwd_round(&self, jr: usize) -> usize {
        self.table.rounds() - 1 - jr
    }

    /// Visit the blocks this rank sends in reversed round `jr` (= the
    /// blocks it would have *received* in the mirrored all-broadcast
    /// round). Only non-empty destinations are scanned.
    fn for_each_send(&self, i: usize, mut f: impl FnMut(usize, usize, usize)) {
        let (k, delta) = self.table.round_params(i);
        for &j in self.nonempty.iter() {
            if j == self.rank {
                continue; // our own destination's partials stay here
            }
            if let Some(b) = self.table.cap(self.table.recv_fast(self.rel(j), k, delta)) {
                let len = self.geoms[j].len(b);
                if len > 0 {
                    f(j, b, len);
                }
            }
        }
    }

    /// True iff this rank receives anything in reversed round (early-exit).
    fn receives_in(&self, i: usize, t: usize) -> bool {
        let (k, delta) = self.table.round_params(i);
        for &j in self.nonempty.iter() {
            if j == t {
                continue;
            }
            if let Some(b) = self.table.cap(self.table.send_fast(self.rel(j), k, delta)) {
                if self.geoms[j].len(b) > 0 {
                    return true;
                }
            }
        }
        false
    }

    /// This rank's reduced chunk (destination `rank`).
    pub fn into_chunk(self) -> Vec<T> {
        let j = self.rank;
        self.partial.into_iter().nth(j).unwrap()
    }
}

impl<T: Element> RankProc<T> for ReduceScatterProc<T> {
    fn send(&mut self, jr: usize) -> Option<Msg<T>> {
        let i = self.fwd_round(jr);
        let p = self.table.p();
        let k = self.table.slot(i);
        // Reversed edge: in the mirrored round we received from
        // (rank - skip[k]); now we send our partials back to it.
        let to = (self.rank + p - self.table.sk.skip(k)) % p;
        let mut data: Vec<T> = Vec::new();
        let geoms = &self.geoms;
        let partial = &self.partial;
        self.for_each_send(i, |j, b, len| {
            let (off, _) = geoms[j].range(b);
            data.extend_from_slice(&partial[j][off..off + len]);
        });
        if data.is_empty() {
            return None;
        }
        Some(Msg { to, data })
    }

    fn expects(&self, jr: usize) -> Option<usize> {
        let i = self.fwd_round(jr);
        let p = self.table.p();
        let k = self.table.slot(i);
        let t = (self.rank + self.table.sk.skip(k)) % p;
        if !self.receives_in(i, t) {
            return None;
        }
        Some(t)
    }

    fn recv(&mut self, jr: usize, _from: usize, data: Vec<T>) {
        let i = self.fwd_round(jr);
        let p = self.table.p();
        let k = self.table.slot(i);
        let t = (self.rank + self.table.sk.skip(k)) % p;
        let rank = self.rank;
        let table = self.table.clone();
        let nonempty = self.nonempty.clone();
        let (kk, delta) = table.round_params(i);
        let mut off = 0usize;
        for &j in nonempty.iter() {
            if j == t {
                continue;
            }
            let rel = { let t = rank + p - j; if t >= p { t - p } else { t } };
            if let Some(b) = table.cap(table.send_fast(rel, kk, delta)) {
                let len = self.geoms[j].len(b);
                if len > 0 {
                    let (boff, _) = self.geoms[j].range(b);
                    self.op
                        .combine(&mut self.partial[j][boff..boff + len], &data[off..off + len]);
                    off += len;
                }
            }
        }
        assert_eq!(off, data.len(), "rank {rank} round {jr}: payload size mismatch");
    }

    fn rounds(&self) -> usize {
        self.table.rounds()
    }
}

/// Build all `p` rank state machines over one shared [`ScheduleTable`] —
/// the shared construction loop used by the [`crate::comm`] backends (the
/// SPMD plane builds one machine per rank over a rank-local table instead).
pub fn build_reduce_scatter_procs<T: Element>(
    table: Arc<ScheduleTable>,
    counts: Arc<Vec<usize>>,
    inputs: &[Vec<T>],
    op: Arc<dyn ReduceOp<T>>,
) -> Vec<ReduceScatterProc<T>> {
    crate::comm::build_procs(table.p(), |r| {
        ReduceScatterProc::new(table.clone(), counts.clone(), r, &inputs[r], op.clone())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::common::SumOp;
    use crate::comm::{Algo, Communicator, ReduceScatterReq};
    use crate::sim::cost::UnitCost;

    fn check_reduce_scatter(counts: &[usize], n: usize) {
        let p = counts.len();
        let total: usize = counts.iter().sum();
        let inputs: Vec<Vec<i64>> = (0..p)
            .map(|r| (0..total).map(|i| (r * 31 + i * 7) as i64 % 1001).collect())
            .collect();
        // Expected: elementwise sum, then chunked by counts.
        let sums: Vec<i64> = (0..total).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
        let comm = Communicator::builder(p).cost_model(UnitCost).build();
        let out = comm
            .reduce_scatter(
                ReduceScatterReq::new(&inputs, counts, Arc::new(SumOp))
                    .algo(Algo::Circulant)
                    .blocks(n),
            )
            .unwrap();
        let mut off = 0usize;
        for r in 0..p {
            assert_eq!(
                out.buffers[r],
                sums[off..off + counts[r]].to_vec(),
                "rank {r} counts={counts:?} n={n}"
            );
            off += counts[r];
        }
        if p > 1 {
            let q = crate::schedule::ceil_log2(p);
            assert_eq!(out.stats.rounds, n - 1 + q);
        }
    }

    #[test]
    fn reduce_scatter_block_grid() {
        for p in 1..=14 {
            for n in [1usize, 2, 4] {
                check_reduce_scatter(&vec![12; p], n);
            }
        }
    }

    #[test]
    fn reduce_scatter_irregular() {
        for p in [7usize, 9, 17] {
            let counts: Vec<usize> = (0..p).map(|i| (i % 3) * 9).collect();
            for n in [1usize, 3] {
                check_reduce_scatter(&counts, n);
            }
        }
    }

    #[test]
    fn reduce_scatter_degenerate() {
        for p in [5usize, 9] {
            let mut counts = vec![0usize; p];
            counts[1] = 60;
            check_reduce_scatter(&counts, 4);
        }
    }

    #[test]
    fn reduce_scatter_paper_sizes() {
        check_reduce_scatter(&vec![8; 17], 5);
        check_reduce_scatter(&vec![8; 18], 5);
        check_reduce_scatter(&[3, 0, 17, 1, 0, 0, 64, 2, 9], 4);
    }

    #[test]
    fn reduce_scatter_volume_optimal() {
        // Observation 1.4: p-1 blocks sent and received per rank (n = 1,
        // equal blocks): total messages' volume = p(p-1) blocks.
        use crate::comm::ReduceScatterBlockReq;
        let p = 16usize;
        let b = 4usize;
        let inputs: Vec<Vec<i64>> =
            (0..p).map(|r| (0..p * b).map(|i| (r + i) as i64).collect()).collect();
        let comm = Communicator::builder(p).cost_model(UnitCost).build();
        let out = comm
            .reduce_scatter_block(
                ReduceScatterBlockReq::new(&inputs, b, Arc::new(SumOp))
                    .algo(Algo::Circulant)
                    .blocks(1),
            )
            .unwrap();
        let total_blocks = out.stats.bytes / (8 * b);
        assert_eq!(total_blocks, p * (p - 1), "volume should be exactly p(p-1) blocks");
    }
}
