//! Block-count selection — the paper's §3 tuning rules plus the
//! cost-model-optimal choice.
//!
//! The paper picks, for `MPI_Bcast`, block *size* `F · sqrt(m / q)` for an
//! empirical constant `F` (70 in Fig. 1), i.e. `n ≈ sqrt(m·q) / F`; for
//! `MPI_Allgatherv` it picks `n = sqrt(m·q) / G` (G = 40 in Fig. 2).
//! Under the linear model the exact optimum for the `n-1+q`-round pipeline
//! minimising `(n-1+q)(α + β·m·s/n)` is `n* = sqrt(β·m·s·(q-1)/α)` — both
//! are provided, and the block-size ablation bench contrasts them.

use crate::schedule::ceil_log2;

/// Clamp a candidate block count into `[1, max(m, 1)]`.
fn clamp_n(n: f64, m: usize) -> usize {
    let hi = m.max(1);
    (n.round() as usize).clamp(1, hi)
}

/// The paper's broadcast rule: block size `F·sqrt(m/q)` elements, hence
/// `n = m / (F·sqrt(m/q)) = sqrt(m·q)/F`.
pub fn bcast_blocks_paper(m: usize, p: usize, f_const: f64) -> usize {
    if p <= 1 || m == 0 {
        return 1;
    }
    let q = ceil_log2(p) as f64;
    clamp_n((m as f64 * q).sqrt() / f_const, m)
}

/// The paper's all-gatherv rule: `n = sqrt(m·q)/G` blocks (`m` = total
/// data over all ranks).
pub fn allgatherv_blocks_paper(m_total: usize, p: usize, g_const: f64) -> usize {
    if p <= 1 || m_total == 0 {
        return 1;
    }
    let q = ceil_log2(p) as f64;
    clamp_n((m_total as f64 * q).sqrt() / g_const, m_total)
}

/// Linear-cost-model optimum for the `n-1+q` round pipeline over an
/// `m`-element, `elem_bytes`-per-element buffer:
/// `T(n) = (n-1+q)(α + β·B/n)` with `B = m·elem_bytes` is minimised at
/// `n* = sqrt(β·B·(q-1)/α)`.
pub fn bcast_blocks_model(
    m: usize,
    p: usize,
    elem_bytes: usize,
    alpha: f64,
    beta: f64,
) -> usize {
    if p <= 1 || m == 0 {
        return 1;
    }
    let q = ceil_log2(p) as f64;
    let bytes = (m * elem_bytes) as f64;
    clamp_n((beta * bytes * (q - 1.0).max(1.0) / alpha).sqrt(), m)
}

/// Predicted pipeline time under the linear model (for quick what-if
/// analysis without running the simulator).
pub fn pipeline_time_model(
    m: usize,
    n: usize,
    p: usize,
    elem_bytes: usize,
    alpha: f64,
    beta: f64,
) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    let q = ceil_log2(p) as f64;
    let n = n.max(1) as f64;
    let block_bytes = (m * elem_bytes) as f64 / n;
    (n - 1.0 + q) * (alpha + beta * block_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_rule_scales_with_sqrt_m() {
        let n1 = bcast_blocks_paper(1 << 16, 256, 70.0);
        let n2 = bcast_blocks_paper(1 << 20, 256, 70.0);
        // m grows 16x => n grows ~4x.
        let ratio = n2 as f64 / n1 as f64;
        assert!((3.0..5.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(bcast_blocks_paper(0, 16, 70.0), 1);
        assert_eq!(bcast_blocks_paper(100, 1, 70.0), 1);
        assert_eq!(allgatherv_blocks_paper(0, 16, 40.0), 1);
        assert_eq!(bcast_blocks_model(0, 16, 4, 1e-6, 1e-10), 1);
    }

    #[test]
    fn model_optimum_beats_neighbors() {
        // n* from the model should (weakly) beat n*/2 and 2n* under the
        // model-predicted time.
        let (m, p, eb, a, b) = (1 << 20, 300, 4usize, 2e-6, 1e-10);
        let n = bcast_blocks_model(m, p, eb, a, b);
        let t = pipeline_time_model(m, n, p, eb, a, b);
        let t_half = pipeline_time_model(m, (n / 2).max(1), p, eb, a, b);
        let t_double = pipeline_time_model(m, n * 2, p, eb, a, b);
        assert!(t <= t_half * 1.001, "t={t} t_half={t_half}");
        assert!(t <= t_double * 1.001, "t={t} t_double={t_double}");
    }

    #[test]
    fn n_clamped_to_m() {
        assert!(bcast_blocks_paper(4, 1 << 20, 0.0001) <= 4);
    }
}
