//! Block-count selection — the paper's §3 tuning rules plus the
//! cost-model-optimal choice.
//!
//! The paper picks, for `MPI_Bcast`, block *size* `F · sqrt(m / q)` for an
//! empirical constant `F` (70 in Fig. 1), i.e. `n ≈ sqrt(m·q) / F`; for
//! `MPI_Allgatherv` it picks `n = sqrt(m·q) / G` (G = 40 in Fig. 2).
//! Under the linear model the exact optimum for the `n-1+q`-round pipeline
//! minimising `(n-1+q)(α + β·m·s/n)` is `n* = sqrt(β·m·s·(q-1)/α)` — both
//! are provided, and the block-size ablation bench contrasts them.

use crate::schedule::{ceil_log2, OptTree};
use crate::sim::cost::LogPParams;

/// Clamp a candidate block count into `[1, max(m, 1)]`.
fn clamp_n(n: f64, m: usize) -> usize {
    let hi = m.max(1);
    (n.round() as usize).clamp(1, hi)
}

/// The paper's broadcast rule: block size `F·sqrt(m/q)` elements, hence
/// `n = m / (F·sqrt(m/q)) = sqrt(m·q)/F`.
pub fn bcast_blocks_paper(m: usize, p: usize, f_const: f64) -> usize {
    if p <= 1 || m == 0 {
        return 1;
    }
    let q = ceil_log2(p) as f64;
    clamp_n((m as f64 * q).sqrt() / f_const, m)
}

/// The paper's all-gatherv rule: `n = sqrt(m·q)/G` blocks (`m` = total
/// data over all ranks).
pub fn allgatherv_blocks_paper(m_total: usize, p: usize, g_const: f64) -> usize {
    if p <= 1 || m_total == 0 {
        return 1;
    }
    let q = ceil_log2(p) as f64;
    clamp_n((m_total as f64 * q).sqrt() / g_const, m_total)
}

/// Linear-cost-model optimum for the `n-1+q` round pipeline over an
/// `m`-element, `elem_bytes`-per-element buffer:
/// `T(n) = (n-1+q)(α + β·B/n)` with `B = m·elem_bytes` is minimised at
/// `n* = sqrt(β·B·(q-1)/α)`.
pub fn bcast_blocks_model(
    m: usize,
    p: usize,
    elem_bytes: usize,
    alpha: f64,
    beta: f64,
) -> usize {
    if p <= 1 || m == 0 {
        return 1;
    }
    let q = ceil_log2(p) as f64;
    let bytes = (m * elem_bytes) as f64;
    clamp_n((beta * bytes * (q - 1.0).max(1.0) / alpha).sqrt(), m)
}

/// Predicted pipeline time under the linear model (for quick what-if
/// analysis without running the simulator).
pub fn pipeline_time_model(
    m: usize,
    n: usize,
    p: usize,
    elem_bytes: usize,
    alpha: f64,
    beta: f64,
) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    let q = ceil_log2(p) as f64;
    let n = n.max(1) as f64;
    let block_bytes = (m * elem_bytes) as f64 / n;
    (n - 1.0 + q) * (alpha + beta * block_bytes)
}

// ---------------------------------------------------------------------
// LogP closed-form predictors — the cost plane's per-family estimates
// ---------------------------------------------------------------------
//
// One function per algorithm family, each returning the predicted
// completion time (seconds) of moving `total_bytes` of payload across
// `p` ranks under `params`. `Algo::Auto` argmins over the applicable
// families when LogP parameters are configured
// (`crate::comm::Algo::resolve_with`); the bench gate in
// `benches/costmodel.rs` cross-checks the predictions' *ordering*
// against `LogPClock`-measured traces.

/// Minimum spacing between consecutive same-size messages on one port:
/// `max(o, packets·g)`.
#[inline]
fn port_spacing(bytes: usize, params: &LogPParams) -> f64 {
    (LogPParams::packets(bytes) as f64 * params.g).max(params.o)
}

/// Circulant pipeline (`n − 1 + q` rounds, one `total/n`-byte block per
/// message): the first block reaches the last rank after `q` dependent
/// hops, the remaining `n − 1` blocks stream behind it at port rate.
pub fn predict_circulant(p: usize, n: usize, total_bytes: usize, params: &LogPParams) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    let n = n.max(1);
    let q = ceil_log2(p);
    let block = (total_bytes + n - 1) / n;
    q as f64 * params.msg_time(block) + (n - 1) as f64 * port_spacing(block, params)
}

/// Binomial tree: `q` dependent hops of the full buffer.
pub fn predict_binomial(p: usize, total_bytes: usize, params: &LogPParams) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    ceil_log2(p) as f64 * params.msg_time(total_bytes)
}

/// van de Geijn: binomial scatter of halving chunks, then a `p − 1`
/// round ring all-gather of `total/p` chunks.
pub fn predict_vdg(p: usize, total_bytes: usize, params: &LogPParams) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    let q = ceil_log2(p);
    let scatter: f64 = (1..=q).map(|t| params.msg_time(total_bytes >> t)).sum();
    scatter + (p - 1) as f64 * params.msg_time(total_bytes / p)
}

/// Ring: `p − 1` dependent rounds of `total/p`-byte chunks.
pub fn predict_ring(p: usize, total_bytes: usize, params: &LogPParams) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    (p - 1) as f64 * params.msg_time(total_bytes / p)
}

/// Recursive halving: `q` exchanges of halving chunks.
pub fn predict_rhalving(p: usize, total_bytes: usize, params: &LogPParams) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    (1..=ceil_log2(p)).map(|k| params.msg_time(total_bytes >> k)).sum()
}

/// Karp optimal tree: the greedy construction's own completion label on
/// the machine scaled for `total_bytes`-sized payloads — exact under
/// the [`crate::sim::LogPClock`] by construction, not an estimate.
pub fn predict_opttree(p: usize, total_bytes: usize, params: &LogPParams) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    OptTree::build(p, &params.scaled_for(total_bytes)).completion()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_rule_scales_with_sqrt_m() {
        let n1 = bcast_blocks_paper(1 << 16, 256, 70.0);
        let n2 = bcast_blocks_paper(1 << 20, 256, 70.0);
        // m grows 16x => n grows ~4x.
        let ratio = n2 as f64 / n1 as f64;
        assert!((3.0..5.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(bcast_blocks_paper(0, 16, 70.0), 1);
        assert_eq!(bcast_blocks_paper(100, 1, 70.0), 1);
        assert_eq!(allgatherv_blocks_paper(0, 16, 40.0), 1);
        assert_eq!(bcast_blocks_model(0, 16, 4, 1e-6, 1e-10), 1);
    }

    #[test]
    fn model_optimum_beats_neighbors() {
        // n* from the model should (weakly) beat n*/2 and 2n* under the
        // model-predicted time.
        let (m, p, eb, a, b) = (1 << 20, 300, 4usize, 2e-6, 1e-10);
        let n = bcast_blocks_model(m, p, eb, a, b);
        let t = pipeline_time_model(m, n, p, eb, a, b);
        let t_half = pipeline_time_model(m, (n / 2).max(1), p, eb, a, b);
        let t_double = pipeline_time_model(m, n * 2, p, eb, a, b);
        assert!(t <= t_half * 1.001, "t={t} t_half={t_half}");
        assert!(t <= t_double * 1.001, "t={t} t_double={t_double}");
    }

    #[test]
    fn n_clamped_to_m() {
        assert!(bcast_blocks_paper(4, 1 << 20, 0.0001) <= 4);
    }

    #[test]
    fn predictors_degenerate_at_p1() {
        let params = LogPParams::default();
        assert_eq!(predict_circulant(1, 8, 1 << 20, &params), 0.0);
        assert_eq!(predict_binomial(1, 1 << 20, &params), 0.0);
        assert_eq!(predict_vdg(1, 1 << 20, &params), 0.0);
        assert_eq!(predict_ring(1, 1 << 20, &params), 0.0);
        assert_eq!(predict_rhalving(1, 1 << 20, &params), 0.0);
        assert_eq!(predict_opttree(1, 1 << 20, &params), 0.0);
    }

    #[test]
    fn predicted_crossover_matches_the_folklore() {
        // Small single-packet payload: trees (opttree ≤ binomial) beat
        // the pipeline and vdG; huge payload: the pipelined circulant
        // with a good n beats both trees.
        let params = LogPParams::default();
        let p = 64;
        let small = 64usize;
        let t_tree = predict_opttree(p, small, &params);
        assert!(t_tree <= predict_binomial(p, small, &params) + 1e-15);
        assert!(t_tree < predict_circulant(p, 8, small, &params));

        let big = 64 << 20;
        let n = bcast_blocks_paper(big / 4, p, 70.0);
        let t_pipe = predict_circulant(p, n, big, &params);
        assert!(t_pipe < predict_binomial(p, big, &params));
        assert!(t_pipe < predict_opttree(p, big, &params));
    }

    #[test]
    fn predictions_monotone_in_each_logp_knob() {
        let base = LogPParams::default();
        let bigger_l = LogPParams::new(base.l * 10.0, base.o, base.g);
        let bigger_o = LogPParams::new(base.l, base.o * 10.0, base.g);
        let bigger_g = LogPParams::new(base.l, base.o, base.g * 10.0);
        let (p, bytes) = (48, 1 << 20);
        for predict in [
            predict_binomial as fn(usize, usize, &LogPParams) -> f64,
            predict_vdg,
            predict_ring,
            predict_rhalving,
            predict_opttree,
        ] {
            let t = predict(p, bytes, &base);
            assert!(predict(p, bytes, &bigger_l) >= t);
            assert!(predict(p, bytes, &bigger_o) >= t);
            assert!(predict(p, bytes, &bigger_g) >= t);
        }
        let t = predict_circulant(p, 16, bytes, &base);
        assert!(predict_circulant(p, 16, bytes, &bigger_l) >= t);
        assert!(predict_circulant(p, 16, bytes, &bigger_o) >= t);
        assert!(predict_circulant(p, 16, bytes, &bigger_g) >= t);
    }
}
