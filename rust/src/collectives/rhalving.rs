//! Recursive-halving reduce-scatter — the power-of-two-padded algorithm
//! the paper contrasts with in Observation 1.4 ("previous algorithms ...
//! have almost twice the communication volume [16] for certain numbers of
//! processes p").
//!
//! For `p = 2^q` the algorithm is volume-optimal (each rank sends
//! `(p-1)/p` of its vector over `q` rounds of halving exchanges). For
//! non-powers-of-two, the classical fix folds the `p - 2^⌊log p⌋` excess
//! ranks into neighbours first (one full-vector exchange!), which is what
//! produces the up-to-2x volume the paper's circulant algorithm avoids —
//! quantified in `benches/ablation_volume.rs`.

use std::sync::Arc;

use crate::sim::network::{Msg, RankProc};

use super::common::{Element, ReduceOp};

/// Phase-tracked state machine for recursive-halving reduce-scatter with
/// power-of-two folding (equal chunks of `chunk` elements per rank).
pub struct RhalvingProc<T> {
    rank: usize,
    p: usize,
    /// Largest power of two <= p.
    pof2: usize,
    /// Excess ranks folded away in the pre-step: ranks < 2*excess pair up.
    excess: usize,
    chunk: usize,
    op: Arc<dyn ReduceOp<T>>,
    /// Full working vector (p * chunk), accumulated in place.
    vec_: Vec<T>,
    /// This rank's id in the folded 2^k group (usize::MAX if folded away).
    newrank: usize,
    /// Current chunk-range [lo, hi) this rank is responsible for.
    lo: usize,
    hi: usize,
    /// Final result chunk for folded-away ranks comes back in a post step.
    done_chunk: Option<Vec<T>>,
}

impl<T: Element> RhalvingProc<T> {
    pub fn new(
        p: usize,
        rank: usize,
        chunk: usize,
        input: &[T],
        op: Arc<dyn ReduceOp<T>>,
    ) -> Self {
        assert_eq!(input.len(), p * chunk);
        let pof2 = if p.is_power_of_two() { p } else { p.next_power_of_two() / 2 };
        let excess = p - pof2;
        // Folding: ranks 0..2*excess pair up (even absorbs odd); ranks
        // >= 2*excess keep newrank = rank - excess.
        let newrank = if rank < 2 * excess {
            if rank % 2 == 0 {
                rank / 2
            } else {
                usize::MAX // folded away
            }
        } else {
            rank - excess
        };
        RhalvingProc {
            rank,
            p,
            pof2,
            excess,
            chunk,
            op,
            vec_: input.to_vec(),
            newrank,
            lo: 0,
            hi: p,
            done_chunk: None,
        }
    }

    /// Number of halving rounds.
    fn qrounds(&self) -> usize {
        self.pof2.trailing_zeros() as usize
    }

    /// Absolute rank of folded-group id `nr`.
    fn abs_of(&self, nr: usize) -> usize {
        if nr < self.excess {
            2 * nr
        } else {
            nr + self.excess
        }
    }

    /// The rank's final chunk after completion.
    pub fn into_chunk(self) -> Vec<T> {
        if let Some(c) = self.done_chunk {
            return c;
        }
        let r = self.rank;
        self.vec_[r * self.chunk..(r + 1) * self.chunk].to_vec()
    }

    /// Chunk-range split for halving round `t` (0-based): ranges halve
    /// around the bit `qrounds-1-t` of newrank.
    fn split(&self, t: usize) -> (usize, usize, usize) {
        // Ranks are grouped by the top bits of newrank; in round t the
        // group size is pof2 >> t and we exchange with partner differing
        // in bit (qrounds-1-t).
        let bit = self.qrounds() - 1 - t;
        let partner_nr = self.newrank ^ (1 << bit);
        // The vector range owned by a group is proportional.
        (bit, partner_nr, 0)
    }

    /// Chunk range (in folded-group coordinates mapped to absolute chunks)
    /// for group id prefix at round t. We keep ranges in *absolute chunk*
    /// space: the group of newranks sharing the top t+1 bits owns an
    /// equal slice of the p chunks... For simplicity (and exact volume
    /// accounting) ranges are computed over `pof2` equal super-chunks,
    /// each super-chunk being the concatenation of the absolute chunks of
    /// the ranks it folds.
    fn range_of(&self, nr_prefix: usize, t: usize) -> (usize, usize) {
        let groups = 1usize << (t + 1);
        let per = self.pof2 / groups;
        let g = nr_prefix >> (self.qrounds() - 1 - t);
        (g * per, (g + 1) * per)
    }

    /// Elements of the super-chunk range [lo, hi) (in folded ids).
    fn gather_range(&self, lo: usize, hi: usize) -> Vec<T> {
        let mut out = Vec::new();
        for nr in lo..hi {
            let a = self.abs_of(nr);
            out.extend_from_slice(&self.vec_[a * self.chunk..(a + 1) * self.chunk]);
            if nr < self.excess {
                // Super-chunk also carries the folded odd partner's chunk.
                let b = a + 1;
                out.extend_from_slice(&self.vec_[b * self.chunk..(b + 1) * self.chunk]);
            }
        }
        out
    }

    fn combine_range(&mut self, lo: usize, hi: usize, data: &[T]) {
        let mut off = 0usize;
        for nr in lo..hi {
            let a = self.abs_of(nr);
            let s = a * self.chunk;
            self.op.combine(&mut self.vec_[s..s + self.chunk], &data[off..off + self.chunk]);
            off += self.chunk;
            if nr < self.excess {
                let s = (a + 1) * self.chunk;
                self.op
                    .combine(&mut self.vec_[s..s + self.chunk], &data[off..off + self.chunk]);
                off += self.chunk;
            }
        }
        debug_assert_eq!(off, data.len());
    }
}

impl<T: Element> RankProc<T> for RhalvingProc<T> {
    fn send(&mut self, round: usize) -> Option<Msg<T>> {
        let q = self.qrounds();
        if round == 0 && self.excess > 0 {
            // Fold pre-step: odd ranks < 2*excess send their FULL vector
            // to the even partner — the 2x-volume culprit.
            if self.rank < 2 * self.excess && self.rank % 2 == 1 {
                return Some(Msg { to: self.rank - 1, data: self.vec_.clone() });
            }
            return None;
        }
        let pre = usize::from(self.excess > 0);
        if round >= pre && round < pre + q {
            if self.newrank == usize::MAX {
                return None;
            }
            let t = round - pre;
            let (_, partner_nr, _) = self.split(t);
            // Send the half the PARTNER keeps.
            let (lo, hi) = self.range_of(partner_nr, t);
            let data = self.gather_range(lo, hi);
            return Some(Msg { to: self.abs_of(partner_nr), data });
        }
        // Post-step: even folded ranks send the odd partner's final chunk.
        if round == pre + q && self.excess > 0 {
            if self.rank < 2 * self.excess && self.rank % 2 == 0 {
                let b = self.rank + 1;
                return Some(Msg {
                    to: b,
                    data: self.vec_[b * self.chunk..(b + 1) * self.chunk].to_vec(),
                });
            }
        }
        None
    }

    fn expects(&self, round: usize) -> Option<usize> {
        let q = self.qrounds();
        if round == 0 && self.excess > 0 {
            if self.rank < 2 * self.excess && self.rank % 2 == 0 {
                return Some(self.rank + 1);
            }
            return None;
        }
        let pre = usize::from(self.excess > 0);
        if round >= pre && round < pre + q {
            if self.newrank == usize::MAX {
                return None;
            }
            let t = round - pre;
            let (_, partner_nr, _) = self.split(t);
            return Some(self.abs_of(partner_nr));
        }
        if round == pre + q && self.excess > 0 {
            if self.rank < 2 * self.excess && self.rank % 2 == 1 {
                return Some(self.rank - 1);
            }
        }
        None
    }

    fn recv(&mut self, round: usize, _from: usize, data: Vec<T>) {
        let q = self.qrounds();
        if round == 0 && self.excess > 0 {
            // Fold: combine the odd partner's full vector.
            let d = data;
            self.op.combine(&mut self.vec_, &d);
            return;
        }
        let pre = usize::from(self.excess > 0);
        if round >= pre && round < pre + q {
            let t = round - pre;
            // We keep OUR half.
            let (lo, hi) = self.range_of(self.newrank, t);
            self.combine_range(lo, hi, &data);
            return;
        }
        // Post-step: folded-away rank receives its final chunk.
        self.done_chunk = Some(data);
    }

    fn rounds(&self) -> usize {
        if self.p == 1 {
            return 0;
        }
        let pre = usize::from(self.excess > 0);
        self.qrounds() + pre + usize::from(self.excess > 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::common::SumOp;
    use crate::comm::{Algo, Communicator, ReduceScatterBlockReq};
    use crate::sim::UnitCost;

    fn rhalving(
        inputs: &[Vec<i64>],
        chunk: usize,
    ) -> (crate::sim::RunStats, Vec<Vec<i64>>) {
        let comm = Communicator::builder(inputs.len()).cost_model(UnitCost).build();
        let out = comm
            .reduce_scatter_block(
                ReduceScatterBlockReq::new(inputs, chunk, Arc::new(SumOp))
                    .algo(Algo::RecursiveHalving),
            )
            .unwrap();
        (out.stats, out.buffers)
    }

    fn check(p: usize, chunk: usize) {
        let total = p * chunk;
        let inputs: Vec<Vec<i64>> = (0..p)
            .map(|r| (0..total).map(|i| ((r + 1) * (i + 7) % 613) as i64).collect())
            .collect();
        let sums: Vec<i64> =
            (0..total).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
        let (_, chunks) = rhalving(&inputs, chunk);
        for r in 0..p {
            assert_eq!(chunks[r], sums[r * chunk..(r + 1) * chunk].to_vec(), "p={p} r={r}");
        }
    }

    #[test]
    fn pow2_correct() {
        for p in [2usize, 4, 8, 16, 32] {
            check(p, 5);
        }
    }

    #[test]
    fn non_pow2_correct() {
        for p in [3usize, 5, 6, 7, 9, 12, 17, 18, 33] {
            check(p, 4);
        }
    }

    #[test]
    fn p1_trivial() {
        check(1, 6);
    }

    #[test]
    fn volume_excess_for_non_pof2() {
        // The paper's point ("almost twice the communication volume [16]
        // for certain numbers of processes"): for p just *below* a power
        // of two, nearly half the ranks fold and each folded pair moves a
        // full extra vector through one port — the per-rank bottleneck
        // volume inflates ~1.5x, while the circulant algorithm stays at
        // the optimal p-1 blocks through every port for every p.
        let chunk = 16usize;
        let circulant = |inputs: &[Vec<i64>]| {
            let comm = Communicator::builder(inputs.len()).cost_model(UnitCost).build();
            comm.reduce_scatter_block(
                ReduceScatterBlockReq::new(inputs, chunk, Arc::new(SumOp))
                    .algo(Algo::Circulant)
                    .blocks(1),
            )
            .unwrap()
            .stats
        };
        for p in [15usize, 31, 63] {
            let inputs: Vec<Vec<i64>> =
                (0..p).map(|r| (0..p * chunk).map(|i| (r + i) as i64).collect()).collect();
            let (rh, _) = rhalving(&inputs, chunk);
            let circ = circulant(&inputs);
            assert!(
                rh.bytes >= circ.bytes,
                "p={p}: rh bytes={} circ bytes={}",
                rh.bytes,
                circ.bytes
            );
            assert!(
                rh.max_rank_bytes as f64 > 1.4 * circ.max_rank_bytes as f64,
                "p={p}: rh max/rank={} circ max/rank={}",
                rh.max_rank_bytes,
                circ.max_rank_bytes
            );
        }
        // And for p just above a power of two, the overhead is small —
        // both algorithms near-optimal (the "certain p" qualifier).
        let p = 17usize;
        let inputs: Vec<Vec<i64>> =
            (0..p).map(|r| (0..p * chunk).map(|i| (r + i) as i64).collect()).collect();
        let (rh, _) = rhalving(&inputs, chunk);
        let circ = circulant(&inputs);
        assert!((rh.bytes as f64) < 1.1 * circ.bytes as f64);
    }
}
