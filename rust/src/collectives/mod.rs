//! MPI-style collectives on circulant-graph broadcast schedules — the
//! paper's Observation 1 applications plus their classical baselines.
//!
//! | paper operation | module | MPI analogue |
//! |---|---|---|
//! | Algorithm 1 pipelined broadcast | [`bcast`] | `MPI_Bcast` |
//! | Algorithm 7 all-broadcast | [`allgatherv`] | `MPI_Allgather(v)` |
//! | reversed-schedule reduction (Obs. 1.3) | [`reduce`] | `MPI_Reduce` |
//! | reversed all-broadcast (Obs. 1.4) | [`reduce_scatter`] | `MPI_Reduce_scatter(_block)` |
//! | reduce-scatter + all-gather | [`allreduce`] | `MPI_Allreduce` |
//! | binomial / van de Geijn / ring comparators | [`baselines`] | native library algorithms |
//! | block-count selection (§3) | [`tuning`] | — |
//!
//! **Run collectives through [`crate::comm::Communicator`]** — the typed,
//! schedule-caching front door — or, for the paper's per-processor SPMD
//! model, through [`crate::comm::RankComm`]. This module provides the
//! per-rank state machines and the shared `build_*_procs` construction
//! loops. (The legacy `*_sim` free functions and `bcast_procs` finished
//! their one-release deprecation cycle and were removed.)

pub mod allgatherv;
pub mod allreduce;
pub mod baselines;
pub mod bcast;
pub mod common;
pub mod hierarchical;
pub mod reduce;
pub mod reduce_scatter;
pub mod rhalving;
pub mod tuning;

pub use allgatherv::{build_allgatherv_procs, AllgathervProc, ScheduleTable};
pub use bcast::{build_bcast_procs, BcastProc};
pub use common::{
    BlockGeometry, Element, MaxOp, PhasedSchedule, ReduceOp, ScheduleSource, SumOp, World,
};
pub use reduce::{build_reduce_procs, ReduceProc};
pub use reduce_scatter::{build_reduce_scatter_procs, ReduceScatterProc};
