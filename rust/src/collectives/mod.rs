//! MPI-style collectives on circulant-graph broadcast schedules — the
//! paper's Observation 1 applications plus their classical baselines.
//!
//! | paper operation | module | MPI analogue |
//! |---|---|---|
//! | Algorithm 1 pipelined broadcast | [`bcast`] | `MPI_Bcast` |
//! | Algorithm 7 all-broadcast | [`allgatherv`] | `MPI_Allgather(v)` |
//! | reversed-schedule reduction (Obs. 1.3) | [`reduce`] | `MPI_Reduce` |
//! | reversed all-broadcast (Obs. 1.4) | [`reduce_scatter`] | `MPI_Reduce_scatter(_block)` |
//! | reduce-scatter + all-gather | [`allreduce`] | `MPI_Allreduce` |
//! | binomial / van de Geijn / ring comparators | [`baselines`] | native library algorithms |
//! | block-count selection (§3) | [`tuning`] | — |

pub mod allgatherv;
pub mod allreduce;
pub mod baselines;
pub mod bcast;
pub mod common;
pub mod reduce;
pub mod reduce_scatter;
pub mod tuning;

pub use allgatherv::{allgather_sim, allgatherv_sim, AllgathervProc, ScheduleTable};
pub use allreduce::allreduce_sim;
pub use bcast::{bcast_procs, bcast_sim, BcastProc};
pub use common::{BlockGeometry, Element, MaxOp, PhasedSchedule, ReduceOp, SumOp, World};
pub use reduce::{reduce_sim, ReduceProc};
pub use reduce_scatter::{reduce_scatter_block_sim, reduce_scatter_sim, ReduceScatterProc};
pub mod rhalving;
pub mod hierarchical;
