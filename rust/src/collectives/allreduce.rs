//! All-reduce composed from the paper's primitives: reduce-scatter (the
//! reversed all-broadcast) followed by all-gather (the all-broadcast) on
//! the same circulant pattern — the classical bandwidth-optimal
//! decomposition (Rabenseifner-style), here with both halves running in
//! the optimal `n - 1 + q` rounds each.
//!
//! This is the gradient-allreduce building block used by the end-to-end
//! example (data-parallel training traffic).

use std::sync::Arc;

use crate::sim::cost::CostModel;
use crate::sim::network::{Network, RunStats, SimError};

use super::allgatherv::{AllgathervProc, ScheduleTable};
use super::common::{Element, ReduceOp, World};
use super::reduce_scatter::ReduceScatterProc;

/// Result of a simulated all-reduce.
pub struct AllreduceResult<T> {
    /// Stats of the reduce-scatter half.
    pub rs_stats: RunStats,
    /// Stats of the all-gather half.
    pub ag_stats: RunStats,
    /// `buffers[r]` = the fully reduced vector at rank `r`.
    pub buffers: Vec<Vec<T>>,
}

impl<T> AllreduceResult<T> {
    /// Combined simulated time.
    pub fn time(&self) -> f64 {
        self.rs_stats.time + self.ag_stats.time
    }

    /// Combined rounds.
    pub fn rounds(&self) -> usize {
        self.rs_stats.rounds + self.ag_stats.rounds
    }
}

/// Run all-reduce over `p` ranks: every rank contributes `inputs[r]` (all
/// the same length `m`); every rank ends with the elementwise reduction.
/// The vector is chunked over ranks (`counts` as equal as possible), each
/// chunk divided into `n` blocks.
pub fn allreduce_sim<T: Element>(
    inputs: &[Vec<T>],
    n: usize,
    op: Arc<dyn ReduceOp<T>>,
    elem_bytes: usize,
    cost: &dyn CostModel,
) -> Result<AllreduceResult<T>, SimError> {
    let p = inputs.len();
    let m = inputs[0].len();
    assert!(inputs.iter().all(|v| v.len() == m));

    // Chunk m over p ranks as equally as possible.
    let base = m / p;
    let rem = m % p;
    let counts: Vec<usize> = (0..p).map(|j| base + usize::from(j < rem)).collect();
    let counts = Arc::new(counts);

    let world = World::new(p);
    let table = ScheduleTable::build(&world, n);

    // Phase 1: reduce-scatter.
    let mut rs_procs: Vec<ReduceScatterProc<T>> = (0..p)
        .map(|r| {
            ReduceScatterProc::new(table.clone(), counts.clone(), r, &inputs[r], op.clone())
        })
        .collect();
    let mut net = Network::new(p);
    let rs_stats = net.run(&mut rs_procs, elem_bytes, cost)?;
    let chunks: Vec<Vec<T>> = rs_procs.into_iter().map(|pr| pr.into_chunk()).collect();

    // Phase 2: all-gather of the reduced chunks.
    let mut ag_procs: Vec<AllgathervProc<T>> = (0..p)
        .map(|r| AllgathervProc::new(table.clone(), counts.clone(), r, &chunks[r]))
        .collect();
    let ag_stats = net.run(&mut ag_procs, elem_bytes, cost)?;
    let buffers = ag_procs
        .into_iter()
        .map(|pr| {
            let rows = pr.into_buffers();
            let mut out = Vec::with_capacity(m);
            for row in rows {
                out.extend_from_slice(&row);
            }
            out
        })
        .collect();

    Ok(AllreduceResult { rs_stats, ag_stats, buffers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::common::SumOp;
    use crate::sim::cost::UnitCost;

    fn check_allreduce(p: usize, m: usize, n: usize) {
        let inputs: Vec<Vec<i64>> = (0..p)
            .map(|r| (0..m).map(|i| ((r + 1) * (i + 1)) as i64 % 503).collect())
            .collect();
        let expect: Vec<i64> = (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
        let res = allreduce_sim(&inputs, n, Arc::new(SumOp), 8, &UnitCost).unwrap();
        for r in 0..p {
            assert_eq!(res.buffers[r], expect, "p={p} m={m} n={n} rank={r}");
        }
    }

    #[test]
    fn allreduce_grid() {
        for p in [1usize, 2, 3, 5, 9, 16, 17] {
            for n in [1usize, 3] {
                check_allreduce(p, 60, n);
            }
        }
    }

    #[test]
    fn allreduce_m_not_divisible() {
        check_allreduce(7, 61, 2);
        check_allreduce(9, 100, 4);
    }

    #[test]
    fn allreduce_round_count() {
        let p = 17usize;
        let m = 170usize;
        let n = 5usize;
        let inputs: Vec<Vec<i64>> = (0..p).map(|_| vec![1i64; m]).collect();
        let res = allreduce_sim(&inputs, n, Arc::new(SumOp), 8, &UnitCost).unwrap();
        let q = crate::schedule::ceil_log2(p);
        assert_eq!(res.rounds(), 2 * (n - 1 + q));
    }
}
