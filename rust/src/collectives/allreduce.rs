//! All-reduce composed from the paper's primitives: reduce-scatter (the
//! reversed all-broadcast) followed by all-gather (the all-broadcast) on
//! the same circulant pattern — the classical bandwidth-optimal
//! decomposition (Rabenseifner-style), here with both halves running in
//! the optimal `n - 1 + q` rounds each.
//!
//! This is the gradient-allreduce building block used by the end-to-end
//! example (data-parallel training traffic). The front door for running
//! it is [`crate::comm::Communicator::allreduce`]; both phases share one
//! cached [`super::allgatherv::ScheduleTable`] there. The per-rank SPMD
//! form is [`crate::comm::RankComm::allreduce`]. (The legacy
//! `allreduce_sim` wrapper finished its deprecation cycle and was
//! removed.)

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use crate::collectives::common::SumOp;
    use crate::comm::{Algo, AllreduceReq, Communicator};
    use crate::sim::cost::UnitCost;

    fn check_allreduce(p: usize, m: usize, n: usize) {
        let inputs: Vec<Vec<i64>> = (0..p)
            .map(|r| (0..m).map(|i| ((r + 1) * (i + 1)) as i64 % 503).collect())
            .collect();
        let expect: Vec<i64> = (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
        let comm = Communicator::builder(p).cost_model(UnitCost).build();
        let out = comm
            .allreduce(
                AllreduceReq::new(&inputs, Arc::new(SumOp)).algo(Algo::Circulant).blocks(n),
            )
            .unwrap();
        for r in 0..p {
            assert_eq!(out.buffers[r], expect, "p={p} m={m} n={n} rank={r}");
        }
    }

    #[test]
    fn allreduce_grid() {
        for p in [1usize, 2, 3, 5, 9, 16, 17] {
            for n in [1usize, 3] {
                check_allreduce(p, 60, n);
            }
        }
    }

    #[test]
    fn allreduce_m_not_divisible() {
        check_allreduce(7, 61, 2);
        check_allreduce(9, 100, 4);
    }

    #[test]
    fn allreduce_round_count() {
        let p = 17usize;
        let m = 170usize;
        let n = 5usize;
        let inputs: Vec<Vec<i64>> = (0..p).map(|_| vec![1i64; m]).collect();
        let comm = Communicator::builder(p).cost_model(UnitCost).build();
        let out = comm
            .allreduce(
                AllreduceReq::new(&inputs, Arc::new(SumOp)).algo(Algo::Circulant).blocks(n),
            )
            .unwrap();
        let q = crate::schedule::ceil_log2(p);
        // Two phases of n - 1 + q rounds each.
        assert_eq!(out.rounds, 2 * (n - 1 + q));
    }
}
