//! All-reduce composed from the paper's primitives: reduce-scatter (the
//! reversed all-broadcast) followed by all-gather (the all-broadcast) on
//! the same circulant pattern — the classical bandwidth-optimal
//! decomposition (Rabenseifner-style), here with both halves running in
//! the optimal `n - 1 + q` rounds each.
//!
//! This is the gradient-allreduce building block used by the end-to-end
//! example (data-parallel training traffic). The front door for running
//! it is [`crate::comm::Communicator::allreduce`]; both phases share one
//! cached [`super::allgatherv::ScheduleTable`] there.

use std::sync::Arc;

use crate::comm::{Algo, AllreduceReq, CommError, Communicator};
use crate::sim::cost::CostModel;
use crate::sim::network::{RunStats, SimError};

use super::common::{Element, ReduceOp};

/// Result of a simulated all-reduce.
pub struct AllreduceResult<T> {
    /// Stats of the reduce-scatter half.
    pub rs_stats: RunStats,
    /// Stats of the all-gather half.
    pub ag_stats: RunStats,
    /// `buffers[r]` = the fully reduced vector at rank `r`.
    pub buffers: Vec<Vec<T>>,
}

impl<T> AllreduceResult<T> {
    /// Combined simulated time.
    pub fn time(&self) -> f64 {
        self.rs_stats.time + self.ag_stats.time
    }

    /// Combined rounds.
    pub fn rounds(&self) -> usize {
        self.rs_stats.rounds + self.ag_stats.rounds
    }
}

/// Run all-reduce over `p` ranks: every rank contributes `inputs[r]` (all
/// the same length `m`); every rank ends with the elementwise reduction.
/// The vector is chunked over ranks (`counts` as equal as possible), each
/// chunk divided into `n` blocks.
#[deprecated(
    since = "0.2.0",
    note = "build a persistent `comm::Communicator` and call \
            `.allreduce(AllreduceReq::new(inputs, op))`; it reuses cached schedules across calls"
)]
pub fn allreduce_sim<T: Element>(
    inputs: &[Vec<T>],
    n: usize,
    op: Arc<dyn ReduceOp<T>>,
    elem_bytes: usize,
    cost: &dyn CostModel,
) -> Result<AllreduceResult<T>, SimError> {
    let comm = Communicator::new(inputs.len());
    let req = AllreduceReq::new(inputs, op)
        .blocks(n)
        .algo(Algo::Circulant)
        .elem_bytes(elem_bytes);
    match comm.allreduce_parts_with(req, cost) {
        Ok((rs_stats, ag_stats, buffers, _)) => {
            Ok(AllreduceResult { rs_stats, ag_stats, buffers })
        }
        Err(CommError::Sim(e)) => Err(e),
        Err(e) => panic!("allreduce_sim: {e}"),
    }
}

// The module tests deliberately exercise the deprecated wrapper: it pins
// the delegation to `comm::Communicator` to the historical behavior.
#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::collectives::common::SumOp;
    use crate::sim::cost::UnitCost;

    fn check_allreduce(p: usize, m: usize, n: usize) {
        let inputs: Vec<Vec<i64>> = (0..p)
            .map(|r| (0..m).map(|i| ((r + 1) * (i + 1)) as i64 % 503).collect())
            .collect();
        let expect: Vec<i64> = (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();
        let res = allreduce_sim(&inputs, n, Arc::new(SumOp), 8, &UnitCost).unwrap();
        for r in 0..p {
            assert_eq!(res.buffers[r], expect, "p={p} m={m} n={n} rank={r}");
        }
    }

    #[test]
    fn allreduce_grid() {
        for p in [1usize, 2, 3, 5, 9, 16, 17] {
            for n in [1usize, 3] {
                check_allreduce(p, 60, n);
            }
        }
    }

    #[test]
    fn allreduce_m_not_divisible() {
        check_allreduce(7, 61, 2);
        check_allreduce(9, 100, 4);
    }

    #[test]
    fn allreduce_round_count() {
        let p = 17usize;
        let m = 170usize;
        let n = 5usize;
        let inputs: Vec<Vec<i64>> = (0..p).map(|_| vec![1i64; m]).collect();
        let res = allreduce_sim(&inputs, n, Arc::new(SumOp), 8, &UnitCost).unwrap();
        let q = crate::schedule::ceil_log2(p);
        assert_eq!(res.rounds(), 2 * (n - 1 + q));
    }
}
