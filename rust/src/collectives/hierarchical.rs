//! Two-level (hierarchical) broadcast — the paper's declared future work
//! ("versions that are more suitable to systems with hierarchical,
//! non-homogeneous communication systems [15] is ongoing").
//!
//! Decomposition: one circulant pipelined broadcast among the `N` node
//! leaders over the inter-node network, then `N` *concurrent* circulant
//! broadcasts inside the nodes over shared memory. Each phase is the
//! verified Algorithm 1, so correctness is inherited; completion time is
//! `T_inter(N, m, n1) + T_intra(C, m, n2)` since all intra-node
//! broadcasts run in parallel on disjoint resources (the leaders of
//! non-root nodes can start only after receiving the *last* block, which
//! the sum models conservatively... a fully pipelined inter/intra overlap
//! is the open problem the paper alludes to).
//!
//! The flat circulant algorithm ignores the hierarchy: its skips cross
//! node boundaries arbitrarily, paying inter-node α/β for most edges; the
//! hierarchical version pays inter-node costs only `n1-1+⌈log₂N⌉` times
//! on the critical path. `benches/ablation_hierarchical.rs` quantifies
//! the crossover.

use crate::sim::cost::{CostModel, HierarchicalCost};
use crate::sim::network::{Network, RunStats, SimError};

use super::bcast::build_bcast_procs;
use super::common::{BlockGeometry, Element, ScheduleSource, World};
use super::tuning;

/// Root-0 circulant pipelined broadcast over `p` throwaway ranks,
/// returning only the run statistics (the per-phase primitive of the
/// two-level decomposition).
fn phase_bcast_stats<T: Element>(
    p: usize,
    data: &[T],
    n: usize,
    elem_bytes: usize,
    cost: &dyn CostModel,
) -> Result<RunStats, SimError> {
    let world = World::new(p);
    let geom = BlockGeometry::new(data.len(), n.max(1));
    let mut procs = build_bcast_procs(&ScheduleSource::Direct(&world.sk), 0, geom, data);
    Network::new(p).run(&mut procs, elem_bytes, cost)
}

/// Result of the two-phase hierarchical broadcast.
#[derive(Debug, Clone)]
pub struct HierBcastResult {
    pub inter: RunStats,
    pub intra: RunStats,
}

impl HierBcastResult {
    /// Conservative completion time: inter-node phase then the slowest
    /// (= any, they're identical) intra-node phase.
    pub fn time(&self) -> f64 {
        self.inter.time + self.intra.time
    }

    pub fn rounds(&self) -> usize {
        self.inter.rounds + self.intra.rounds
    }

    /// Total bytes across the machine: inter phase + N intra phases.
    pub fn bytes(&self, nodes: usize) -> usize {
        self.inter.bytes + nodes * self.intra.bytes
    }
}

/// Wrapper cost model exposing only the inter-node component of a
/// [`HierarchicalCost`] (used for the leader phase, where consecutive
/// leader ranks live on different nodes).
struct InterOnly<'a>(&'a HierarchicalCost);

impl CostModel for InterOnly<'_> {
    fn msg_time(&self, _from: usize, _to: usize, bytes: usize) -> f64 {
        self.0.inter.alpha + self.0.inter.beta * self.0.nic_share * bytes as f64
    }
    fn name(&self) -> &str {
        "inter-only"
    }
}

/// Intra-node component (ranks within one node).
struct IntraOnly<'a>(&'a HierarchicalCost);

impl CostModel for IntraOnly<'_> {
    fn msg_time(&self, _from: usize, _to: usize, bytes: usize) -> f64 {
        self.0.intra.alpha + self.0.intra.beta * bytes as f64
    }
    fn name(&self) -> &str {
        "intra-only"
    }
}

/// Simulate the hierarchical broadcast of `data` over `nodes × cores`
/// ranks: leader phase with `n1` blocks, intra phase with `n2` blocks
/// (pass 0 for either to use the paper's F-rule on the respective level).
pub fn hier_bcast_sim<T: Element>(
    nodes: usize,
    cores: usize,
    data: &[T],
    n1: usize,
    n2: usize,
    elem_bytes: usize,
    cost: &HierarchicalCost,
) -> Result<HierBcastResult, SimError> {
    let m = data.len();
    // Per-level block counts from the α-β optimum of *that level's*
    // parameters (the per-level fabrics differ by orders of magnitude, so
    // a single F constant cannot serve both — this is exactly the tuning
    // freedom the two-level decomposition buys).
    let n1 = if n1 == 0 {
        tuning::bcast_blocks_model(
            m,
            nodes.max(2),
            elem_bytes,
            cost.inter.alpha,
            cost.inter.beta * cost.nic_share,
        )
    } else {
        n1
    };
    let n2 = if n2 == 0 {
        tuning::bcast_blocks_model(m, cores.max(2), elem_bytes, cost.intra.alpha, cost.intra.beta)
    } else {
        n2
    };

    // Phase 1: leaders (one rank per node) over the inter-node fabric.
    let inter = if nodes > 1 {
        phase_bcast_stats(nodes, data, n1, elem_bytes, &InterOnly(cost))?
    } else {
        RunStats::default()
    };

    // Phase 2: every leader broadcasts within its node; all nodes run in
    // parallel on disjoint links, so simulate one representative node.
    let intra = if cores > 1 {
        phase_bcast_stats(cores, data, n2, elem_bytes, &IntraOnly(cost))?
    } else {
        RunStats::default()
    };

    Ok(HierBcastResult { inter, intra })
}

/// The flat circulant broadcast on the same machine, for comparison.
pub fn flat_bcast_time<T: Element>(
    nodes: usize,
    cores: usize,
    data: &[T],
    n: usize,
    elem_bytes: usize,
    cost: &HierarchicalCost,
) -> Result<RunStats, SimError> {
    let p = nodes * cores;
    let n = if n == 0 {
        // Give the flat algorithm its best shot too: model optimum with
        // the (dominant) inter-node parameters.
        tuning::bcast_blocks_model(
            data.len(),
            p,
            elem_bytes,
            cost.inter.alpha,
            cost.inter.beta * cost.nic_share,
        )
    } else {
        n
    };
    phase_bcast_stats(p, data, n, elem_bytes, cost)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hierarchical_correct_phases() {
        let data: Vec<i32> = (0..4096).collect();
        let cost = HierarchicalCost::vega(8);
        let res = hier_bcast_sim(16, 8, &data, 0, 0, 4, &cost).unwrap();
        assert!(res.inter.rounds > 0);
        assert!(res.intra.rounds > 0);
        assert!(res.time() > 0.0);
    }

    #[test]
    fn hierarchical_beats_flat_on_steep_hierarchy() {
        // With a strong intra/inter gap and many cores per node, the
        // two-level decomposition must win: the flat algorithm sends most
        // blocks across the fabric many times.
        let data: Vec<i32> = (0..1 << 16).collect();
        let mut cost = HierarchicalCost::vega(32);
        cost.inter.beta *= 4.0; // steepen the hierarchy
        let hier = hier_bcast_sim(16, 32, &data, 0, 0, 4, &cost).unwrap();
        let flat = flat_bcast_time(16, 32, &data, 0, 4, &cost).unwrap();
        assert!(
            hier.time() < flat.time,
            "hier {:.6}s should beat flat {:.6}s",
            hier.time(),
            flat.time
        );
    }

    #[test]
    fn degenerate_levels() {
        let data: Vec<i32> = (0..128).collect();
        let cost = HierarchicalCost::vega(1);
        // Single node: only intra phase... cores=1 means only inter.
        let res = hier_bcast_sim(4, 1, &data, 2, 2, 4, &cost).unwrap();
        assert_eq!(res.intra.rounds, 0);
        let res = hier_bcast_sim(1, 4, &data, 2, 2, 4, &cost).unwrap();
        assert_eq!(res.inter.rounds, 0);
    }
}
