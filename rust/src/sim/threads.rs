//! Threaded runtime: every simulated rank is a real OS thread exchanging
//! messages over channels — the "distributed" execution mode.
//!
//! Unlike the lockstep [`super::network::Network`], ranks here run
//! asynchronously: rank A can be several rounds ahead of rank B, exactly
//! as MPI processes would be. Messages are tagged with their round number
//! and matched out-of-order on the receive side, so the execution is
//! correct for any interleaving — this validates that the schedules do not
//! depend on global synchrony (the paper's algorithms are round-*numbered*
//! but not barrier-synchronised).
//!
//! The same [`super::network::RankProc`] state machines run unchanged: the
//! driver sends the round's message (channels never block on send) and
//! then blocks on the expected receive.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::Duration;

use super::cost::{CostModel, LogPClock, LogPParams};
use super::network::{Msg, RankProc, RunStats};

/// One round-tagged message in flight.
struct Packet<T> {
    from: usize,
    round: usize,
    data: Vec<T>,
}

/// A rank's communication endpoint in the threaded world.
pub struct Comm<T> {
    rank: usize,
    senders: Vec<mpsc::Sender<Packet<T>>>,
    inbox: mpsc::Receiver<Packet<T>>,
    /// Messages that arrived before the rank asked for them.
    pending: HashMap<(usize, usize), Vec<T>>,
    /// Receive timeout — a blown deadline means a schedule bug (a message
    /// that will never be sent), which we surface as a panic with context.
    timeout: Duration,
}

impl<T: Send> Comm<T> {
    /// Create endpoints for all `p` ranks of a world.
    pub fn world(p: usize, timeout: Duration) -> Vec<Comm<T>> {
        let mut senders = Vec::with_capacity(p);
        let mut receivers = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = mpsc::channel::<Packet<T>>();
            senders.push(tx);
            receivers.push(rx);
        }
        receivers
            .into_iter()
            .enumerate()
            .map(|(rank, inbox)| Comm {
                rank,
                senders: senders.clone(),
                inbox,
                pending: HashMap::new(),
                timeout,
            })
            .collect()
    }

    #[inline]
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Non-blocking send of `data` to `to`, tagged with `round`.
    pub fn send(&self, to: usize, round: usize, data: Vec<T>) {
        assert_ne!(to, self.rank, "self-message from rank {}", self.rank);
        self.senders[to]
            .send(Packet { from: self.rank, round, data })
            .expect("peer hung up — rank thread died");
    }

    /// Blocking receive of the message from `from` tagged `round`;
    /// out-of-order arrivals are buffered.
    pub fn recv(&mut self, from: usize, round: usize) -> Vec<T> {
        if let Some(data) = self.pending.remove(&(from, round)) {
            return data;
        }
        loop {
            let pkt = self
                .inbox
                .recv_timeout(self.timeout)
                .unwrap_or_else(|e| {
                    panic!(
                        "rank {}: timeout waiting for (from={from}, round={round}): {e}",
                        self.rank
                    )
                });
            if pkt.from == from && pkt.round == round {
                return pkt.data;
            }
            self.pending.insert((pkt.from, pkt.round), pkt.data);
        }
    }
}

/// The one driving loop: send, then block on the expected receive, per
/// round. `on_send` observes each send as `(round, to, payload elements)`
/// — a no-op for plain [`drive`], a log append for
/// [`run_threaded_stats`]'s cost accounting.
fn drive_with<T: Send, P: RankProc<T>>(
    proc_: &mut P,
    comm: &mut Comm<T>,
    mut on_send: impl FnMut(usize, usize, usize),
) {
    let rounds = proc_.rounds();
    for round in 0..rounds {
        if let Some(Msg { to, data }) = proc_.send(round) {
            on_send(round, to, data.len());
            comm.send(to, round, data);
        }
        if let Some(from) = proc_.expects(round) {
            let data = comm.recv(from, round);
            proc_.recv(round, from, data);
        }
    }
}

/// Drive one rank's [`RankProc`] over its `Comm` endpoint to completion.
pub fn drive<T: Send, P: RankProc<T>>(proc_: &mut P, comm: &mut Comm<T>) {
    drive_with(proc_, comm, |_, _, _| {});
}

/// [`drive`] plus a send log for [`run_threaded_stats`].
fn drive_logged<T: Send, P: RankProc<T>>(
    proc_: &mut P,
    comm: &mut Comm<T>,
) -> Vec<(usize, usize, usize)> {
    let mut log = Vec::new();
    drive_with(proc_, comm, |round, to, elems| log.push((round, to, elems)));
    log
}

/// Run all ranks on real threads *and* produce the same [`RunStats`] the
/// lockstep [`super::network::Network`] would: each thread logs its sends
/// (round, target, payload); afterwards the logs are folded with the
/// identical per-round `max` / total `sum` cost accounting. This is what
/// lets the threaded runtime act as a drop-in
/// [`crate::comm::ExecBackend`].
///
/// Machine-model violations panic the offending rank thread (and then
/// this function) instead of returning an error — full enforcement is the
/// lockstep backend's job.
pub fn run_threaded_stats<T, P>(
    procs: Vec<P>,
    elem_bytes: usize,
    cost: &dyn CostModel,
) -> (RunStats, Vec<P>)
where
    T: Send + 'static,
    P: RankProc<T> + Send + 'static,
{
    run_threaded_stats_logp(procs, elem_bytes, cost, None)
}

/// [`run_threaded_stats`] with the cost plane attached: the folded logs
/// are additionally clocked by a [`super::cost::LogPClock`] when `logp`
/// is given (`RunStats::logp_time`).
pub fn run_threaded_stats_logp<T, P>(
    procs: Vec<P>,
    elem_bytes: usize,
    cost: &dyn CostModel,
    logp: Option<&LogPParams>,
) -> (RunStats, Vec<P>)
where
    T: Send + 'static,
    P: RankProc<T> + Send + 'static,
{
    let p = procs.len();
    let total_rounds = procs.iter().map(|pr| pr.rounds()).max().unwrap_or(0);
    let comms = Comm::<T>::world(p, Duration::from_secs(30));
    let handles: Vec<_> = procs
        .into_iter()
        .zip(comms)
        .map(|(mut pr, mut comm)| {
            std::thread::spawn(move || {
                let log = drive_logged(&mut pr, &mut comm);
                (pr, log)
            })
        })
        .collect();
    let mut done = Vec::with_capacity(p);
    let mut logs = Vec::with_capacity(p);
    for h in handles {
        let (pr, log) = h.join().expect("rank thread panicked");
        done.push(pr);
        logs.push(log);
    }

    (fold_send_logs(&logs, total_rounds, elem_bytes, cost, logp), done)
}

/// Fold per-rank send logs — `logs[from]` lists that rank's
/// `(round, to, elems)` sends — into the exact [`RunStats`] the lockstep
/// [`super::network::Network`] computes for the same messages: per-round
/// `max` message cost summed over active rounds, total/ per-rank byte
/// accounting, message counts. The one accounting definition shared by
/// the threaded runtime and the SPMD rank plane
/// ([`crate::comm::rank`]), which is what makes their statistics
/// bit-identical to a lockstep run by construction.
pub(crate) fn fold_send_logs(
    logs: &[Vec<(usize, usize, usize)>],
    total_rounds: usize,
    elem_bytes: usize,
    cost: &dyn CostModel,
    logp: Option<&LogPParams>,
) -> RunStats {
    let mut stats = RunStats { rounds: total_rounds, ..Default::default() };
    let mut round_time = vec![0.0f64; total_rounds];
    let mut round_any = vec![false; total_rounds];
    let mut rank_bytes = vec![0usize; logs.len()];
    for (from, log) in logs.iter().enumerate() {
        for &(round, to, elems) in log {
            let bytes = elems * elem_bytes;
            stats.messages += 1;
            stats.bytes += bytes;
            rank_bytes[from] += bytes;
            rank_bytes[to] += bytes;
            round_any[round] = true;
            round_time[round] = round_time[round].max(cost.msg_time(from, to, bytes));
        }
    }
    for (any, t) in round_any.iter().zip(&round_time) {
        if *any {
            stats.active_rounds += 1;
            stats.time += t;
        }
    }
    stats.max_rank_bytes = rank_bytes.into_iter().max().unwrap_or(0);
    // The LogP clock needs the messages in machine-round order; the
    // per-rank logs are each round-sorted, so bucket by round and replay.
    if let Some(params) = logp {
        let mut clock = LogPClock::new(*params);
        let mut by_round: Vec<Vec<(usize, usize, usize)>> = vec![Vec::new(); total_rounds];
        for (from, log) in logs.iter().enumerate() {
            for &(round, to, elems) in log {
                by_round[round].push((from, to, elems * elem_bytes));
            }
        }
        for round in by_round {
            for (from, to, bytes) in round {
                clock.msg(from, to, bytes);
            }
            clock.end_round();
        }
        stats.logp_time = Some(clock.total());
    }
    stats
}

/// Run all ranks' state machines on real threads; returns the final state
/// machines for inspection.
pub fn run_threaded<T, P>(procs: Vec<P>) -> Vec<P>
where
    T: Send + 'static,
    P: RankProc<T> + Send + 'static,
{
    let p = procs.len();
    let comms = Comm::<T>::world(p, Duration::from_secs(30));
    let handles: Vec<_> = procs
        .into_iter()
        .zip(comms)
        .map(|(mut pr, mut comm)| {
            std::thread::spawn(move || {
                drive(&mut pr, &mut comm);
                pr
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().expect("rank thread panicked")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Round-robin token passing, threaded.
    struct Token {
        rank: usize,
        p: usize,
        have: Vec<u64>,
    }

    impl RankProc<u64> for Token {
        fn send(&mut self, round: usize) -> Option<Msg<u64>> {
            // In round i, rank i sends its token to rank i+1.
            if round == self.rank {
                Some(Msg { to: (self.rank + 1) % self.p, data: self.have.clone() })
            } else {
                None
            }
        }
        fn expects(&self, round: usize) -> Option<usize> {
            if round + 1 == self.rank || (self.rank == 0 && round == self.p - 1) {
                Some(round)
            } else {
                None
            }
        }
        fn recv(&mut self, _round: usize, _from: usize, mut data: Vec<u64>) {
            data.push(self.rank as u64);
            self.have = data;
        }
        fn rounds(&self) -> usize {
            self.p
        }
    }

    #[test]
    fn token_ring_threaded() {
        let p = 7;
        let procs: Vec<Token> =
            (0..p).map(|rank| Token { rank, p, have: vec![rank as u64] }).collect();
        let done = run_threaded(procs);
        // Rank 0 received the token last; it accumulated every rank.
        assert_eq!(done[0].have, vec![0, 1, 2, 3, 4, 5, 6, 0]);
    }

    #[test]
    fn out_of_order_delivery_buffered() {
        // Rank 0 sends rounds 0 and 1 to rank 1 immediately; rank 1 first
        // asks for round 1, then round 0 — pending buffer must serve both.
        let mut comms = Comm::<u8>::world(2, Duration::from_secs(5));
        let c1 = comms.pop().unwrap();
        let c0 = comms.pop().unwrap();
        let t = std::thread::spawn(move || {
            c0.send(1, 0, vec![10]);
            c0.send(1, 1, vec![11]);
        });
        let mut c1 = c1;
        assert_eq!(c1.recv(0, 1), vec![11]);
        assert_eq!(c1.recv(0, 0), vec![10]);
        t.join().unwrap();
    }
}
