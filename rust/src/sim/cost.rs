//! Communication cost models for the simulated machine.
//!
//! The paper's machine model is fully connected, one-ported and
//! send/receive bidirectional: in each communication round every processor
//! can send one message and receive one message. Completion time of a
//! round is the maximum over its messages of the per-message cost; total
//! time is the sum over rounds (all algorithms here are round-synchronous).
//!
//! Two concrete models:
//!
//! * [`LinearCost`] — the classical α-β model, `α + β·bytes` per message
//!   (the paper's "linear cost model" used to pick block counts).
//! * [`HierarchicalCost`] — nodes × cores-per-node: intra-node messages
//!   use a cheaper (α,β) than inter-node ones. This is the substitute for
//!   the paper's VEGA (200 nodes × 128 cores) and small-cluster (36 × 32)
//!   testbeds; it reproduces the Fig. 1/Fig. 2 regimes where flat
//!   (non-hierarchical) circulant algorithms still win on round count.

/// Per-message cost model; round time is the max over the round's
/// messages, total time the sum over rounds.
pub trait CostModel: Send + Sync {
    /// Time for one message of `bytes` bytes from rank `from` to rank `to`.
    fn msg_time(&self, from: usize, to: usize, bytes: usize) -> f64;

    /// Human-readable name for reports.
    fn name(&self) -> &str;
}

/// Classical linear α-β model: every message costs `alpha + beta * bytes`.
#[derive(Debug, Clone)]
pub struct LinearCost {
    /// Start-up latency per message, seconds.
    pub alpha: f64,
    /// Per-byte transfer time, seconds (1/bandwidth).
    pub beta: f64,
}

impl LinearCost {
    pub fn new(alpha: f64, beta: f64) -> Self {
        LinearCost { alpha, beta }
    }

    /// A default resembling a commodity HPC interconnect: 2 µs latency,
    /// 10 GB/s effective per-port bandwidth.
    pub fn hpc_default() -> Self {
        LinearCost { alpha: 2e-6, beta: 1e-10 }
    }
}

impl CostModel for LinearCost {
    #[inline]
    fn msg_time(&self, _from: usize, _to: usize, bytes: usize) -> f64 {
        self.alpha + self.beta * bytes as f64
    }

    fn name(&self) -> &str {
        "linear"
    }
}

/// Hierarchical model: `nodes` × `cores` ranks, block-distributed (rank
/// `r` lives on node `r / cores`). Messages between ranks on the same node
/// are cheap (shared memory), inter-node messages pay the network (α,β);
/// additionally a node's NIC is shared, so inter-node messages are slowed
/// by the number of concurrent inter-node messages from the same node in
/// the same round — approximated by the static factor `nic_share` set from
/// cores-per-node (the paper's full-node configs show exactly this
/// contention effect).
#[derive(Debug, Clone)]
pub struct HierarchicalCost {
    pub cores: usize,
    pub intra: LinearCost,
    pub inter: LinearCost,
    /// Multiplier on inter-node β modelling NIC sharing by concurrent
    /// per-core streams (1.0 = no contention modelled).
    pub nic_share: f64,
}

impl HierarchicalCost {
    /// VEGA-like: EPYC nodes, 100 Gb/s-class fabric, fast shared memory.
    pub fn vega(cores: usize) -> Self {
        HierarchicalCost {
            cores,
            intra: LinearCost { alpha: 4e-7, beta: 2e-11 },
            inter: LinearCost { alpha: 2e-6, beta: 8e-11 },
            // Every core that talks off-node in the same round shares the
            // NIC; in the worst case all `cores` do.
            nic_share: (cores as f64).sqrt(),
        }
    }

    /// Small cluster (36 × 32, dual Omni-Path) used for Fig. 2.
    pub fn small_cluster(cores: usize) -> Self {
        HierarchicalCost {
            cores,
            intra: LinearCost { alpha: 3e-7, beta: 2e-11 },
            inter: LinearCost { alpha: 1.5e-6, beta: 1e-11 },
            nic_share: (cores as f64).sqrt(),
        }
    }

    #[inline]
    fn node(&self, r: usize) -> usize {
        r / self.cores
    }
}

impl CostModel for HierarchicalCost {
    #[inline]
    fn msg_time(&self, from: usize, to: usize, bytes: usize) -> f64 {
        if self.node(from) == self.node(to) {
            self.intra.alpha + self.intra.beta * bytes as f64
        } else {
            self.inter.alpha + self.inter.beta * self.nic_share * bytes as f64
        }
    }

    fn name(&self) -> &str {
        "hierarchical"
    }
}

/// Unit cost: every message costs 1 — total time equals the number of
/// rounds in which at least one message flies. Useful to assert the
/// round-optimality results (`n - 1 + ceil(log2 p)` rounds).
#[derive(Debug, Clone, Default)]
pub struct UnitCost;

impl CostModel for UnitCost {
    #[inline]
    fn msg_time(&self, _from: usize, _to: usize, _bytes: usize) -> f64 {
        1.0
    }

    fn name(&self) -> &str {
        "unit"
    }
}

/// Completion-time accounting of an *overlapped batch* of collectives:
/// within one machine round every co-scheduled operation's messages fly
/// simultaneously (the traffic plane's port ledger guarantees they
/// respect one-portedness across operations), so the round costs the max
/// over **all** of those messages of [`CostModel::msg_time`], and the
/// batch completes in the sum over machine rounds — the round-synchronous
/// model of [`super::network`], extended across concurrent operations.
///
/// Usage: per message call [`OverlapClock::msg`]; at the end of each
/// machine round call [`OverlapClock::end_round`]; read
/// [`OverlapClock::total`] when the batch drains. Rounds in which no
/// message flew cost nothing (matching `RunStats::active_rounds`
/// semantics).
#[derive(Debug, Clone, Default)]
pub struct OverlapClock {
    round_max: f64,
    round_any: bool,
    total: f64,
    active_rounds: usize,
}

impl OverlapClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Account one message of the current machine round.
    #[inline]
    pub fn msg(&mut self, cost: &dyn CostModel, from: usize, to: usize, bytes: usize) {
        self.round_max = self.round_max.max(cost.msg_time(from, to, bytes));
        self.round_any = true;
    }

    /// Close the current machine round: fold its max message cost into
    /// the total (if any message flew) and reset for the next round.
    pub fn end_round(&mut self) {
        if self.round_any {
            self.total += self.round_max;
            self.active_rounds += 1;
        }
        self.round_max = 0.0;
        self.round_any = false;
    }

    /// Aggregate completion time of the batch so far, seconds.
    #[inline]
    pub fn total(&self) -> f64 {
        self.total
    }

    /// Machine rounds in which at least one message flew.
    #[inline]
    pub fn active_rounds(&self) -> usize {
        self.active_rounds
    }
}

// ----------------------------------------------------------------------
// LogP: the cost plane's machine description and per-port clock
// ----------------------------------------------------------------------

/// Messages are charged per `LOGP_PACKET_BYTES`-byte packet: the first
/// packet pays the full `L + 2o`, every further packet one more `g` on
/// the wire and on each port (a LogGP-style long-message extension that
/// degenerates to plain LogP for single-packet messages).
pub const LOGP_PACKET_BYTES: usize = 1024;

/// LogP machine description (Karp et al.): `L` wire latency, `o`
/// per-endpoint send/receive overhead, `g` minimum gap between
/// consecutive packets on one port — all in seconds.
///
/// Configure via the env knobs `CBCAST_LOGP_L` / `CBCAST_LOGP_O` /
/// `CBCAST_LOGP_G` (positive decimal seconds; invalid or non-positive
/// values warn once and fall back to that knob's default), or
/// programmatically through `TuningParams::logp`. When *none* of the
/// knobs is set, [`LogPParams::from_env`] returns `None` and the cost
/// plane stays off — `Algo::Auto` keeps the paper's §3 rules and
/// `RunStats::logp_time` stays `None`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LogPParams {
    /// Wire latency, seconds.
    pub l: f64,
    /// Per-endpoint overhead (charged at sender and receiver), seconds.
    pub o: f64,
    /// Gap between consecutive packets on one port, seconds.
    pub g: f64,
}

impl Default for LogPParams {
    /// Commodity-HPC-like defaults: 2 µs latency, 0.5 µs overhead and a
    /// per-1 KiB-packet gap matching ~10 GB/s port bandwidth — the same
    /// regime as [`LinearCost::hpc_default`].
    fn default() -> Self {
        LogPParams { l: 2e-6, o: 5e-7, g: 1e-7 }
    }
}

/// Parse one LogP knob: a positive, finite decimal number of seconds.
/// Pure so the rejection rules are unit-testable without env races.
fn parse_logp_secs(raw: &str) -> Result<f64, String> {
    match raw.trim().parse::<f64>() {
        Ok(v) if v.is_finite() && v > 0.0 => Ok(v),
        Ok(_) => Err("must be a positive number of seconds".to_string()),
        Err(e) => Err(e.to_string()),
    }
}

/// Read one `CBCAST_LOGP_*` knob: `None` if unset, `Some(default)` with
/// a once-per-knob warning if set but invalid (the `CBCAST_THREADS`
/// convention — a typo must not silently reshape the machine model).
fn logp_knob(name: &str, default: f64, warned: &'static std::sync::Once) -> Option<f64> {
    match std::env::var(name) {
        Ok(raw) => match parse_logp_secs(&raw) {
            Ok(v) => Some(v),
            Err(why) => {
                warned.call_once(|| {
                    eprintln!("cbcast: ignoring {name}={raw:?} ({why}); using {default:e} s");
                });
                Some(default)
            }
        },
        Err(_) => None,
    }
}

impl LogPParams {
    pub fn new(l: f64, o: f64, g: f64) -> Self {
        LogPParams { l, o, g }
    }

    /// The configured machine description, or `None` when no
    /// `CBCAST_LOGP_{L,O,G}` knob is set (cost plane off). Knobs that
    /// *are* set but invalid warn once and take that knob's default;
    /// unset knobs silently take the default once any other knob opts
    /// the cost plane in.
    pub fn from_env() -> Option<LogPParams> {
        static WARN_L: std::sync::Once = std::sync::Once::new();
        static WARN_O: std::sync::Once = std::sync::Once::new();
        static WARN_G: std::sync::Once = std::sync::Once::new();
        let d = LogPParams::default();
        let l = logp_knob("CBCAST_LOGP_L", d.l, &WARN_L);
        let o = logp_knob("CBCAST_LOGP_O", d.o, &WARN_O);
        let g = logp_knob("CBCAST_LOGP_G", d.g, &WARN_G);
        if l.is_none() && o.is_none() && g.is_none() {
            return None;
        }
        Some(LogPParams {
            l: l.unwrap_or(d.l),
            o: o.unwrap_or(d.o),
            g: g.unwrap_or(d.g),
        })
    }

    /// Packets a `bytes`-byte message occupies (at least one).
    #[inline]
    pub fn packets(bytes: usize) -> usize {
        // ceil; div_ceil needs 1.73, MSRV is 1.70
        ((bytes + LOGP_PACKET_BYTES - 1) / LOGP_PACKET_BYTES).max(1)
    }

    /// Endpoint-to-endpoint time of one isolated `bytes`-byte message:
    /// `L + 2o + (packets − 1)·g`. This is also the closed-form unit the
    /// `Algo::Auto` predictors are built from.
    #[inline]
    pub fn msg_time(&self, bytes: usize) -> f64 {
        self.l + 2.0 * self.o + (Self::packets(bytes) - 1) as f64 * self.g
    }

    /// The parameters of an *effective* single-packet machine whose
    /// messages are all `bytes` long: in-flight time absorbs the extra
    /// packets' `g`, and the port gap scales to the whole message. Karp's
    /// single-packet optimal-tree greedy run on the scaled machine yields
    /// the optimal tree for `bytes`-sized payloads.
    pub fn scaled_for(&self, bytes: usize) -> LogPParams {
        let packets = Self::packets(bytes) as f64;
        LogPParams {
            l: self.l + (packets - 1.0) * self.g,
            o: self.o,
            g: packets * self.g,
        }
    }
}

/// Per-port LogP completion clock over a round-synchronous message trace
/// — the cost plane's counterpart of [`OverlapClock`].
///
/// Where [`OverlapClock`] charges each machine round the max of its
/// per-message [`CostModel`] costs, `LogPClock` keeps *per-rank
/// send/receive timelines*: each message charges `o` on the sender port,
/// `o` on the receiver port, `g` between consecutive packets on either
/// port and `L` in flight, so pipelined schedules genuinely overlap
/// latency instead of paying it once per round.
///
/// Feed it the same way as [`OverlapClock`]: per message call
/// [`LogPClock::msg`], per machine round [`LogPClock::end_round`], then
/// read [`LogPClock::total`]. Rounds are processed with *snapshot*
/// semantics: a round's sends depend only on data that arrived in
/// earlier rounds (the lockstep contract), so within a round the
/// feeding order of messages does not change the result — each rank
/// sends at most once and receives at most once per round.
#[derive(Debug, Clone)]
pub struct LogPClock {
    params: LogPParams,
    /// Earliest time each rank's send port is free again.
    send_free: Vec<f64>,
    /// Earliest time each rank's receive port is free again.
    recv_free: Vec<f64>,
    /// Time each rank's data (received in rounds `< current`) is ready.
    ready: Vec<f64>,
    /// Messages of the current round: `(from, to, bytes)`.
    round: Vec<(usize, usize, usize)>,
    completion: f64,
    active_rounds: usize,
}

impl LogPClock {
    pub fn new(params: LogPParams) -> Self {
        LogPClock {
            params,
            send_free: Vec::new(),
            recv_free: Vec::new(),
            ready: Vec::new(),
            round: Vec::new(),
            completion: 0.0,
            active_rounds: 0,
        }
    }

    pub fn params(&self) -> &LogPParams {
        &self.params
    }

    fn grow(&mut self, rank: usize) {
        if rank >= self.ready.len() {
            self.send_free.resize(rank + 1, 0.0);
            self.recv_free.resize(rank + 1, 0.0);
            self.ready.resize(rank + 1, 0.0);
        }
    }

    /// Buffer one message of the current machine round (applied at
    /// [`LogPClock::end_round`] under snapshot semantics).
    #[inline]
    pub fn msg(&mut self, from: usize, to: usize, bytes: usize) {
        self.round.push((from, to, bytes));
    }

    /// Close the current machine round: charge every buffered message
    /// against the port timelines. Sends gate on the sender's data as of
    /// the *previous* round's end, so intra-round feeding order is
    /// irrelevant (each rank sends ≤ 1 and receives ≤ 1 per round).
    pub fn end_round(&mut self) {
        if self.round.is_empty() {
            return;
        }
        self.active_rounds += 1;
        let LogPParams { l, o, g } = self.params;
        let msgs = std::mem::take(&mut self.round);
        // Snapshot: sender readiness as of the end of the last round.
        // (One send per rank per round ⇒ send_free/recv_free are each
        // touched at most once below; ready[] updates are deferred.)
        let mut done_updates: Vec<(usize, f64)> = Vec::with_capacity(msgs.len());
        for (from, to, bytes) in msgs {
            self.grow(from.max(to));
            let packets = LogPParams::packets(bytes) as f64;
            let port = (packets * g).max(o);
            let start = self.ready[from].max(self.send_free[from]);
            self.send_free[from] = start + port;
            let arrive = start + o + (packets - 1.0) * g + l;
            let begin = arrive.max(self.recv_free[to]);
            self.recv_free[to] = begin + port;
            let done = begin + o;
            done_updates.push((to, done));
            self.completion = self.completion.max(done);
        }
        for (to, done) in done_updates {
            self.ready[to] = self.ready[to].max(done);
        }
    }

    /// Predicted completion time of everything fed so far, seconds.
    #[inline]
    pub fn total(&self) -> f64 {
        self.completion
    }

    /// Machine rounds in which at least one message flew.
    #[inline]
    pub fn active_rounds(&self) -> usize {
        self.active_rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_cost_monotone_in_bytes() {
        let m = LinearCost::new(1e-6, 1e-9);
        assert!(m.msg_time(0, 1, 10) < m.msg_time(0, 1, 1000));
        assert!((m.msg_time(0, 1, 0) - 1e-6).abs() < 1e-15);
    }

    #[test]
    fn hierarchical_intra_cheaper() {
        let m = HierarchicalCost::vega(128);
        // ranks 0 and 1 share node 0; ranks 0 and 128 do not.
        assert!(m.msg_time(0, 1, 1 << 20) < m.msg_time(0, 128, 1 << 20));
    }

    #[test]
    fn unit_counts_rounds() {
        let m = UnitCost;
        assert_eq!(m.msg_time(3, 5, 12345), 1.0);
    }

    #[test]
    fn overlap_clock_folds_round_maxima() {
        let cost = LinearCost::new(1.0, 0.5);
        let mut clock = OverlapClock::new();
        // Round 0: two overlapped messages; only the max (1 + 0.5*4) counts.
        clock.msg(&cost, 0, 1, 2);
        clock.msg(&cost, 2, 3, 4);
        clock.end_round();
        // Round 1: idle — free.
        clock.end_round();
        // Round 2: one message.
        clock.msg(&cost, 1, 0, 2);
        clock.end_round();
        assert!((clock.total() - (3.0 + 2.0)).abs() < 1e-12);
        assert_eq!(clock.active_rounds(), 2);
    }

    #[test]
    fn overlap_of_unit_cost_counts_active_rounds() {
        // Under UnitCost an overlapped batch's time is exactly its active
        // machine-round count — concurrent ops sharing a round pay once.
        let mut clock = OverlapClock::new();
        for _ in 0..7 {
            clock.msg(&UnitCost, 0, 1, 8);
            clock.msg(&UnitCost, 5, 9, 800);
            clock.end_round();
        }
        assert_eq!(clock.total(), 7.0);
    }

    // ------------------------------------------------------------------
    // LogP
    // ------------------------------------------------------------------

    #[test]
    fn logp_knob_parse_accepts_positive_seconds() {
        assert_eq!(parse_logp_secs("2e-6"), Ok(2e-6));
        assert_eq!(parse_logp_secs(" 0.5 "), Ok(0.5));
        assert_eq!(parse_logp_secs("1"), Ok(1.0));
    }

    #[test]
    fn logp_knob_parse_rejects_zero_negative_and_garbage() {
        // The floor: zero or negative seconds would break the clock's
        // monotone timelines, so they are rejected (warn-once + default
        // at the env layer), as are NaN/inf and non-numbers.
        assert!(parse_logp_secs("0").is_err());
        assert!(parse_logp_secs("-1e-6").is_err());
        assert!(parse_logp_secs("NaN").is_err());
        assert!(parse_logp_secs("inf").is_err());
        assert!(parse_logp_secs("2 us").is_err());
        assert!(parse_logp_secs("").is_err());
    }

    #[test]
    fn logp_packets_and_msg_time() {
        let p = LogPParams::new(1.0, 0.25, 0.125);
        assert_eq!(LogPParams::packets(0), 1);
        assert_eq!(LogPParams::packets(1), 1);
        assert_eq!(LogPParams::packets(LOGP_PACKET_BYTES), 1);
        assert_eq!(LogPParams::packets(LOGP_PACKET_BYTES + 1), 2);
        // Single packet: L + 2o exactly (Karp's point-to-point time).
        assert!((p.msg_time(64) - 1.5).abs() < 1e-12);
        // Three packets: two extra gaps on the wire.
        assert!((p.msg_time(3 * LOGP_PACKET_BYTES) - (1.5 + 0.25)).abs() < 1e-12);
    }

    #[test]
    fn logp_clock_single_hop_is_l_plus_2o() {
        let mut clock = LogPClock::new(LogPParams::new(1.0, 0.25, 0.125));
        clock.msg(0, 1, 8);
        clock.end_round();
        assert!((clock.total() - 1.5).abs() < 1e-12);
        assert_eq!(clock.active_rounds(), 1);
    }

    #[test]
    fn logp_clock_chains_dependent_hops() {
        // 0 → 1 in round 0, 1 → 2 in round 1: the second send gates on
        // the first arrival, so the chain costs 2·(L + 2o).
        let mut clock = LogPClock::new(LogPParams::new(1.0, 0.25, 0.125));
        clock.msg(0, 1, 8);
        clock.end_round();
        clock.msg(1, 2, 8);
        clock.end_round();
        assert!((clock.total() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn logp_clock_charges_send_port_gap() {
        // Root sends to 1 then to 2 in consecutive rounds: the second
        // send waits on the send port (max(o, g)), not on new data, so
        // completion is max(o, g) + L + 2o — Karp's two-child root.
        let params = LogPParams::new(1.0, 0.25, 0.125);
        let mut clock = LogPClock::new(params);
        clock.msg(0, 1, 8);
        clock.end_round();
        clock.msg(0, 2, 8);
        clock.end_round();
        assert!((clock.total() - (0.25 + 1.5)).abs() < 1e-12);

        // With g > o the gap dominates the spacing.
        let mut clock = LogPClock::new(LogPParams::new(1.0, 0.25, 0.5));
        clock.msg(0, 1, 8);
        clock.end_round();
        clock.msg(0, 2, 8);
        clock.end_round();
        assert!((clock.total() - (0.5 + 1.5)).abs() < 1e-12);
    }

    #[test]
    fn logp_clock_intra_round_order_is_irrelevant() {
        // Two independent chains fed in different orders within each
        // round must clock identically (snapshot semantics).
        let params = LogPParams::new(1.0, 0.25, 0.125);
        let mut a = LogPClock::new(params);
        let mut b = LogPClock::new(params);
        a.msg(0, 1, 2048);
        a.msg(2, 3, 8);
        b.msg(2, 3, 8);
        b.msg(0, 1, 2048);
        a.end_round();
        b.end_round();
        a.msg(1, 2, 8);
        a.msg(3, 0, 8);
        b.msg(3, 0, 8);
        b.msg(1, 2, 8);
        a.end_round();
        b.end_round();
        assert_eq!(a.total(), b.total());
        assert_eq!(a.active_rounds(), b.active_rounds());
    }

    #[test]
    fn logp_clock_monotone_in_each_parameter() {
        // A fixed pipelined trace gets strictly slower as any one of
        // L, o, g grows.
        let trace: Vec<(usize, usize, usize)> = (0..6)
            .flat_map(|r| vec![(r % 4, (r + 1) % 4, 4096), ((r + 2) % 4, (r + 3) % 4, 64)])
            .collect();
        let run = |params: LogPParams| {
            let mut clock = LogPClock::new(params);
            for chunk in trace.chunks(2) {
                for &(f, t, b) in chunk {
                    clock.msg(f, t, b);
                }
                clock.end_round();
            }
            clock.total()
        };
        let base = run(LogPParams::new(1.0, 0.25, 0.125));
        assert!(run(LogPParams::new(2.0, 0.25, 0.125)) > base);
        assert!(run(LogPParams::new(1.0, 0.5, 0.125)) > base);
        assert!(run(LogPParams::new(1.0, 0.25, 0.25)) > base);
    }

    #[test]
    fn logp_scaled_machine_matches_packet_charges() {
        let p = LogPParams::new(1.0, 0.25, 0.125);
        let s = p.scaled_for(3 * LOGP_PACKET_BYTES);
        // Same endpoint-to-endpoint time for the full message…
        assert!((s.msg_time(8) - p.msg_time(3 * LOGP_PACKET_BYTES)).abs() < 1e-12);
        // …and the port gap covers all three packets.
        assert!((s.g - 0.375).abs() < 1e-12);
    }
}
