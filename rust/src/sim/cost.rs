//! Communication cost models for the simulated machine.
//!
//! The paper's machine model is fully connected, one-ported and
//! send/receive bidirectional: in each communication round every processor
//! can send one message and receive one message. Completion time of a
//! round is the maximum over its messages of the per-message cost; total
//! time is the sum over rounds (all algorithms here are round-synchronous).
//!
//! Two concrete models:
//!
//! * [`LinearCost`] — the classical α-β model, `α + β·bytes` per message
//!   (the paper's "linear cost model" used to pick block counts).
//! * [`HierarchicalCost`] — nodes × cores-per-node: intra-node messages
//!   use a cheaper (α,β) than inter-node ones. This is the substitute for
//!   the paper's VEGA (200 nodes × 128 cores) and small-cluster (36 × 32)
//!   testbeds; it reproduces the Fig. 1/Fig. 2 regimes where flat
//!   (non-hierarchical) circulant algorithms still win on round count.

/// Per-message cost model; round time is the max over the round's
/// messages, total time the sum over rounds.
pub trait CostModel: Send + Sync {
    /// Time for one message of `bytes` bytes from rank `from` to rank `to`.
    fn msg_time(&self, from: usize, to: usize, bytes: usize) -> f64;

    /// Human-readable name for reports.
    fn name(&self) -> &str;
}

/// Classical linear α-β model: every message costs `alpha + beta * bytes`.
#[derive(Debug, Clone)]
pub struct LinearCost {
    /// Start-up latency per message, seconds.
    pub alpha: f64,
    /// Per-byte transfer time, seconds (1/bandwidth).
    pub beta: f64,
}

impl LinearCost {
    pub fn new(alpha: f64, beta: f64) -> Self {
        LinearCost { alpha, beta }
    }

    /// A default resembling a commodity HPC interconnect: 2 µs latency,
    /// 10 GB/s effective per-port bandwidth.
    pub fn hpc_default() -> Self {
        LinearCost { alpha: 2e-6, beta: 1e-10 }
    }
}

impl CostModel for LinearCost {
    #[inline]
    fn msg_time(&self, _from: usize, _to: usize, bytes: usize) -> f64 {
        self.alpha + self.beta * bytes as f64
    }

    fn name(&self) -> &str {
        "linear"
    }
}

/// Hierarchical model: `nodes` × `cores` ranks, block-distributed (rank
/// `r` lives on node `r / cores`). Messages between ranks on the same node
/// are cheap (shared memory), inter-node messages pay the network (α,β);
/// additionally a node's NIC is shared, so inter-node messages are slowed
/// by the number of concurrent inter-node messages from the same node in
/// the same round — approximated by the static factor `nic_share` set from
/// cores-per-node (the paper's full-node configs show exactly this
/// contention effect).
#[derive(Debug, Clone)]
pub struct HierarchicalCost {
    pub cores: usize,
    pub intra: LinearCost,
    pub inter: LinearCost,
    /// Multiplier on inter-node β modelling NIC sharing by concurrent
    /// per-core streams (1.0 = no contention modelled).
    pub nic_share: f64,
}

impl HierarchicalCost {
    /// VEGA-like: EPYC nodes, 100 Gb/s-class fabric, fast shared memory.
    pub fn vega(cores: usize) -> Self {
        HierarchicalCost {
            cores,
            intra: LinearCost { alpha: 4e-7, beta: 2e-11 },
            inter: LinearCost { alpha: 2e-6, beta: 8e-11 },
            // Every core that talks off-node in the same round shares the
            // NIC; in the worst case all `cores` do.
            nic_share: (cores as f64).sqrt(),
        }
    }

    /// Small cluster (36 × 32, dual Omni-Path) used for Fig. 2.
    pub fn small_cluster(cores: usize) -> Self {
        HierarchicalCost {
            cores,
            intra: LinearCost { alpha: 3e-7, beta: 2e-11 },
            inter: LinearCost { alpha: 1.5e-6, beta: 1e-11 },
            nic_share: (cores as f64).sqrt(),
        }
    }

    #[inline]
    fn node(&self, r: usize) -> usize {
        r / self.cores
    }
}

impl CostModel for HierarchicalCost {
    #[inline]
    fn msg_time(&self, from: usize, to: usize, bytes: usize) -> f64 {
        if self.node(from) == self.node(to) {
            self.intra.alpha + self.intra.beta * bytes as f64
        } else {
            self.inter.alpha + self.inter.beta * self.nic_share * bytes as f64
        }
    }

    fn name(&self) -> &str {
        "hierarchical"
    }
}

/// Unit cost: every message costs 1 — total time equals the number of
/// rounds in which at least one message flies. Useful to assert the
/// round-optimality results (`n - 1 + ceil(log2 p)` rounds).
#[derive(Debug, Clone, Default)]
pub struct UnitCost;

impl CostModel for UnitCost {
    #[inline]
    fn msg_time(&self, _from: usize, _to: usize, _bytes: usize) -> f64 {
        1.0
    }

    fn name(&self) -> &str {
        "unit"
    }
}

/// Completion-time accounting of an *overlapped batch* of collectives:
/// within one machine round every co-scheduled operation's messages fly
/// simultaneously (the traffic plane's port ledger guarantees they
/// respect one-portedness across operations), so the round costs the max
/// over **all** of those messages of [`CostModel::msg_time`], and the
/// batch completes in the sum over machine rounds — the round-synchronous
/// model of [`super::network`], extended across concurrent operations.
///
/// Usage: per message call [`OverlapClock::msg`]; at the end of each
/// machine round call [`OverlapClock::end_round`]; read
/// [`OverlapClock::total`] when the batch drains. Rounds in which no
/// message flew cost nothing (matching `RunStats::active_rounds`
/// semantics).
#[derive(Debug, Clone, Default)]
pub struct OverlapClock {
    round_max: f64,
    round_any: bool,
    total: f64,
    active_rounds: usize,
}

impl OverlapClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Account one message of the current machine round.
    #[inline]
    pub fn msg(&mut self, cost: &dyn CostModel, from: usize, to: usize, bytes: usize) {
        self.round_max = self.round_max.max(cost.msg_time(from, to, bytes));
        self.round_any = true;
    }

    /// Close the current machine round: fold its max message cost into
    /// the total (if any message flew) and reset for the next round.
    pub fn end_round(&mut self) {
        if self.round_any {
            self.total += self.round_max;
            self.active_rounds += 1;
        }
        self.round_max = 0.0;
        self.round_any = false;
    }

    /// Aggregate completion time of the batch so far, seconds.
    #[inline]
    pub fn total(&self) -> f64 {
        self.total
    }

    /// Machine rounds in which at least one message flew.
    #[inline]
    pub fn active_rounds(&self) -> usize {
        self.active_rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_cost_monotone_in_bytes() {
        let m = LinearCost::new(1e-6, 1e-9);
        assert!(m.msg_time(0, 1, 10) < m.msg_time(0, 1, 1000));
        assert!((m.msg_time(0, 1, 0) - 1e-6).abs() < 1e-15);
    }

    #[test]
    fn hierarchical_intra_cheaper() {
        let m = HierarchicalCost::vega(128);
        // ranks 0 and 1 share node 0; ranks 0 and 128 do not.
        assert!(m.msg_time(0, 1, 1 << 20) < m.msg_time(0, 128, 1 << 20));
    }

    #[test]
    fn unit_counts_rounds() {
        let m = UnitCost;
        assert_eq!(m.msg_time(3, 5, 12345), 1.0);
    }

    #[test]
    fn overlap_clock_folds_round_maxima() {
        let cost = LinearCost::new(1.0, 0.5);
        let mut clock = OverlapClock::new();
        // Round 0: two overlapped messages; only the max (1 + 0.5*4) counts.
        clock.msg(&cost, 0, 1, 2);
        clock.msg(&cost, 2, 3, 4);
        clock.end_round();
        // Round 1: idle — free.
        clock.end_round();
        // Round 2: one message.
        clock.msg(&cost, 1, 0, 2);
        clock.end_round();
        assert!((clock.total() - (3.0 + 2.0)).abs() < 1e-12);
        assert_eq!(clock.active_rounds(), 2);
    }

    #[test]
    fn overlap_of_unit_cost_counts_active_rounds() {
        // Under UnitCost an overlapped batch's time is exactly its active
        // machine-round count — concurrent ops sharing a round pay once.
        let mut clock = OverlapClock::new();
        for _ in 0..7 {
            clock.msg(&UnitCost, 0, 1, 8);
            clock.msg(&UnitCost, 5, 9, 800);
            clock.end_round();
        }
        assert_eq!(clock.total(), 7.0);
    }
}
