//! The machine substrate: the paper's fully connected, one-ported,
//! send/receive-bidirectional `p`-processor system, as (a) a lockstep
//! round-based simulator with machine-model enforcement and cost
//! accounting ([`network`]), (b) pluggable cost models ([`cost`]), (c) a
//! threaded runtime where every rank is an OS thread ([`threads`]) and
//! (d) the sparse, zero-copy engine for million-rank full-network
//! simulation of the circulant collectives ([`engine`]).

pub mod cost;
pub mod engine;
pub mod network;
pub mod threads;

pub use cost::{
    CostModel, HierarchicalCost, LinearCost, LogPClock, LogPParams, OverlapClock, UnitCost,
    LOGP_PACKET_BYTES,
};
pub use engine::{CirculantEngine, EngineScratch, EngineStep, ScratchPool};
pub use network::{Msg, Network, RankProc, RunStats, SimError, StepNet};
pub use threads::{run_threaded, run_threaded_stats, run_threaded_stats_logp, Comm};
