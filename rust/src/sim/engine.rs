//! The sparse, zero-copy simulation engine — full-network simulation of
//! the circulant-schedule collectives at million-rank scale.
//!
//! The lockstep [`super::network::Network`] drives `p` boxed state
//! machines by scanning `0..p` every round and cloning a fresh `Vec<T>`
//! per message; that is the right *correctness instrument* but tops out
//! around a few thousand ranks. The paper's point, however, is that
//! schedule computation is O(log p) per rank with no communication — the
//! interesting regime is `p` up to `2^20`, where per-round scans and
//! per-message allocations dominate everything. This engine simulates the
//! same machine model directly on the schedules:
//!
//! * **Active-set worklist** — only ranks that can act in a round are
//!   visited. For broadcast the invariant is: a rank is in the worklist
//!   iff it holds at least one block (ranks join exactly once, when their
//!   first block arrives, and sends of round `j` scan only the ranks
//!   active at the *start* of round `j`, preserving lockstep delivery
//!   order). For reduction (reversed schedules) the worklist is pruned
//!   from the tail as reversed time passes each rank's first forward send
//!   round — computed in closed form from its schedule row, O(log p) per
//!   rank, ordered by a counting sort over rounds (O(p + rounds)), and
//!   memoized per engine ([`CirculantEngine::run_reduce`] reruns pop a
//!   cached copy).
//! * **Arena payload storage, offset-passing sends** — block payloads
//!   live in one flat arena indexed by `(rank, block)` (`rank*m +
//!   BlockGeometry::range(b)`); a "send" passes offsets into the arena
//!   (reduction stages the sender's range through one reused per-round
//!   scratch, mirroring the lockstep clone-at-send semantics without a
//!   per-message allocation). A broadcast never transforms payloads at
//!   all, so its arena degenerates to the caller's buffer plus a
//!   `(rank, block)` *holds* bitmap — the simulation is payload-free.
//! * **Shared schedule plane** — the engine evaluates a
//!   [`ScheduleTable`]: the all-ranks flat `i8` arena built in parallel
//!   once per `p` (see [`crate::schedule::table`]) and shared through an
//!   `Arc` by every engine, root, block count and collective at that `p`.
//!   The per-round phase shift is one `(slot, delta)` pair shared by
//!   every rank ([`crate::collectives::common::phase_params`]), so the
//!   hot path is an `i8` load plus an add.
//! * **Reusable, word-packed run scratch** — all per-run state
//!   (worklists, bitmaps, receive marks, delivery queues, the reduction
//!   arena) lives in an [`EngineScratch`] that callers can hold across
//!   runs, making repeated [`CirculantEngine::run_bcast_with`] /
//!   [`CirculantEngine::run_reduce_with`] calls allocation-free after
//!   the first. Hot per-rank state is packed word-at-a-time: one `u64`
//!   receive mark per rank (round stamp ∥ sender), rank-major `u64`
//!   possession words whose completion check is a `memcmp` per rank,
//!   and 16-byte reduction deliveries (see [`EngineScratch`]).
//! * **Sharded delivery application** — when a round's delivery queue is
//!   large, applying it (bitmap updates for broadcast, ⊕-combines for
//!   reduction) is sharded over `std::thread::scope` threads
//!   ([`crate::schedule::configured_threads`]); one-portedness makes
//!   every round's delivery targets pairwise distinct, so the shards
//!   write disjoint state and the result is bit-identical to the serial
//!   order.
//!
//! ## Accounting and enforcement contract
//!
//! [`RunStats`] accounting is identical to the lockstep [`Network`]: same
//! message/byte counts (empty blocks still count as messages), same
//! per-round `max` / total `sum` cost folding over *absolute* ranks (so
//! hierarchical cost models see the same locality), same
//! `max_rank_bytes`. On machine-model violations the engine returns the
//! same [`SimError`] values: [`SimError::ReceivePortBusy`] and
//! [`SimError::UnexpectedMessage`] abort mid-round exactly like the
//! lockstep simulator; an expected-but-never-sent message surfaces as
//! [`SimError::MissingMessage`] through a *deferred* completion check
//! (per-rank holds bitmap for broadcast, closed-form expected-receive
//! counts for reduction) that reconstructs the earliest offending round.
//! Sending a block that was never received panics, like the proc state
//! machines do. The only divergence is on *broken* schedules, where the
//! deferred checks may report a different (but equally fatal) violation
//! than the mid-round lockstep abort — full round-by-round enforcement
//! remains the lockstep backend's job, exactly as it already is for the
//! threaded runtime.
//!
//! [`Network`]: super::network::Network

use std::any::Any;
use std::sync::{Arc, Mutex, OnceLock};

use crate::collectives::common::{phase_params, BlockGeometry, Element, ReduceOp, ScheduleSource};
use crate::schedule::table::configured_threads;
use crate::schedule::{ScheduleTable, Skips};
use crate::sim::cost::{CostModel, LogPClock, LogPParams};
use crate::sim::network::{RunStats, SimError};

/// Minimum per-round delivery-queue length before applying it is sharded
/// across scoped threads — below this the spawn cost dominates the work.
const PAR_DELIVERY_MIN: usize = 1 << 12;

/// Raw-pointer cell for the sharded delivery application. SAFETY
/// contract at each use site: one round's delivery targets are pairwise
/// distinct (enforced by the one-ported receive check before enqueueing),
/// and the pointed-to layout is target-major, so concurrent shards touch
/// disjoint memory.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Reusable run scratch: every vector the engine's run loops need, owned
/// by the caller so repeated runs on one (or several) engines allocate
/// nothing after the first use. `T` is the reduction element type; for
/// broadcast-only use any `T` (e.g. `EngineScratch::<()>::new()`) — the
/// payload fields stay empty.
///
/// The per-rank state is deliberately packed for the round loops'
/// access patterns: the one-ported receive check reads and writes one
/// `u64` *mark* per target (round stamp in the high half, sender in the
/// low half — a single cache-line touch instead of two parallel
/// arrays), broadcast possession is a rank-major `u64` bitmap whose
/// completion check is a word compare per rank, and a queued reduction
/// delivery is 16 bytes (`(to_rel, block, stage offset)` — the combine
/// length is derivable from the block geometry, so it is not stored).
#[derive(Default)]
pub struct EngineScratch<T> {
    /// Override for the delivery-sharding thread count (`None` = the
    /// `CBCAST_THREADS`/core default). Exists so tests and benches can
    /// pin both code paths deterministically.
    pub delivery_threads: Option<usize>,
    // --- broadcast ---
    holds: Vec<u64>,
    held: Vec<u32>,
    newly: Vec<u8>,
    deliveries_b: Vec<(u32, u32)>,
    // --- shared ---
    active: Vec<u32>,
    /// One-ported receive marks, one word per rank: `stamp << 32 |
    /// sender`. A round-`j` receive is a busy-port violation iff the
    /// high half already equals round `j`'s stamp; the low half then
    /// names the first sender for the error value.
    recv_mark: Vec<u64>,
    rank_bytes: Vec<usize>,
    // --- reduction ---
    recv_count: Vec<u32>,
    arena: Vec<T>,
    stage: Vec<T>,
    deliveries_r: Vec<(u32, u32, usize)>,
}

impl<T: Element> EngineScratch<T> {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Clear and re-zero a scratch vector to `len` — allocation-free once the
/// capacity has been grown by a first run.
fn reset<T: Copy + Default>(v: &mut Vec<T>, len: usize) {
    v.clear();
    v.resize(len, T::default());
}

/// Closed-form per-rank activity profile of a reduction, computed once
/// per engine and shared by every rerun: reversed-schedule senders in
/// worklist order (counting-sorted by first forward send round) plus the
/// expected receive counts for the deferred completion check.
struct ReduceProfile {
    first_send: Vec<usize>,
    expect_recv: Vec<u32>,
    /// Non-root ranks that send at all, ascending by `first_send` (ties
    /// by rank — the exact order the old stable comparison sort gave).
    active: Vec<u32>,
}

/// The engine for one `(p, root, block geometry)` configuration over a
/// shared all-ranks [`ScheduleTable`]: construction is O(1) beyond the
/// `Arc` (the table is built once per `p` and reused across engines,
/// roots, block counts and collectives). Build once, then run broadcasts
/// ([`Self::run_bcast`]) and reductions ([`Self::run_reduce`]) over it.
pub struct CirculantEngine {
    table: Arc<ScheduleTable>,
    sk: Arc<Skips>,
    root: usize,
    geom: BlockGeometry,
    p: usize,
    q: usize,
    n: usize,
    /// Virtual-round offset `x = (q - (n-1) mod q) mod q` of Algorithm 1.
    x: usize,
    rounds: usize,
    reduce_profile: OnceLock<ReduceProfile>,
}

impl CirculantEngine {
    /// Build the engine over a shared all-ranks schedule table, a
    /// broadcast/reduction root and the block geometry.
    pub fn new(table: Arc<ScheduleTable>, root: usize, geom: BlockGeometry) -> Self {
        let sk = table.skips().clone();
        let p = sk.p();
        assert!(root < p, "root {root} out of range for p = {p}");
        let q = sk.q();
        let n = geom.n;
        let x = if q == 0 { 0 } else { (q - (n - 1) % q) % q };
        let rounds = if p == 1 { 0 } else { n - 1 + q };
        CirculantEngine {
            table,
            sk,
            root,
            geom,
            p,
            q,
            n,
            x,
            rounds,
            reduce_profile: OnceLock::new(),
        }
    }

    /// Build from a [`ScheduleSource`] (table-served, cache-served or
    /// direct — see [`ScheduleSource::rows`]).
    pub fn from_source(src: &ScheduleSource<'_>, root: usize, geom: BlockGeometry) -> Self {
        Self::new(src.rows(), root, geom)
    }

    /// Direct-computation convenience (no cache): builds a throwaway
    /// table with the configured parallelism — the million-rank path.
    pub fn from_skips(sk: &Arc<Skips>, root: usize, geom: BlockGeometry) -> Self {
        Self::new(Arc::new(ScheduleTable::build(sk)), root, geom)
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    #[inline]
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// The shared schedule plane this engine evaluates.
    #[inline]
    pub fn table(&self) -> &Arc<ScheduleTable> {
        &self.table
    }

    /// Absolute rank of relative rank `rel`.
    #[inline]
    fn abs(&self, rel: usize) -> usize {
        let t = rel + self.root;
        if t >= self.p {
            t - self.p
        } else {
            t
        }
    }

    /// The round-wide phase constants: slot `k` and the shift `delta`
    /// such that the phased schedule value of any rank at network round
    /// `j` is `row[k] + delta` — the shared Algorithm-1 formula
    /// ([`crate::collectives::common::phase_params`]).
    #[inline]
    fn round_params(&self, j: usize) -> (usize, i64) {
        phase_params(self.q, self.x, j)
    }

    /// Closed-form activity profile of one schedule row: the number of
    /// network rounds `j` in `0..rounds` whose phased value is
    /// non-negative (restricted to slots passing `slot_ok`), and the
    /// earliest such round. O(q) — per slot, the phased value first turns
    /// non-negative at a computable cycle and stays non-negative after.
    fn row_occupancy(&self, row: &[i8], slot_ok: impl Fn(usize) -> bool) -> (usize, usize) {
        let q = self.q;
        let x = self.x;
        let rounds = self.rounds;
        let mut count = 0usize;
        let mut first = usize::MAX;
        for k in 0..q {
            if !slot_ok(k) {
                continue;
            }
            // First network round with slot k, where delta = d0; each
            // later occurrence (every q rounds) adds q to the value.
            let j0 = (k + q - x) % q;
            if j0 >= rounds {
                continue;
            }
            let total = (rounds - 1 - j0) / q + 1;
            let d0 = -(x as i64) + if k < x { q as i64 } else { 0 };
            let v0 = row[k] as i64 + d0;
            let c0 =
                if v0 >= 0 { 0 } else { ((-v0 + q as i64 - 1) / q as i64) as usize };
            if c0 < total {
                count += total - c0;
                first = first.min(j0 + c0 * q);
            }
        }
        (count, first)
    }

    #[inline]
    fn cap(&self, v: i64) -> Option<usize> {
        if v < 0 {
            None
        } else if v as usize >= self.n {
            Some(self.n - 1)
        } else {
            Some(v as usize)
        }
    }

    // ------------------------------------------------------------------
    // Broadcast (Algorithm 1)
    // ------------------------------------------------------------------

    /// Simulate the full `n`-block broadcast over all `p` ranks with a
    /// throwaway scratch. See [`Self::run_bcast_with`].
    pub fn run_bcast(&self, elem_bytes: usize, cost: &dyn CostModel) -> Result<RunStats, SimError> {
        self.run_bcast_with(&mut EngineScratch::<()>::new(), elem_bytes, cost)
    }

    /// Simulate the full `n`-block broadcast over all `p` ranks, reusing
    /// `scratch` (allocation-free after its first use).
    ///
    /// Payload-free: a broadcast moves blocks of the root's buffer
    /// unchanged, so only the `(rank, block)` holds bitmap and the block
    /// *lengths* (for byte/cost accounting) are simulated. Returns the
    /// run statistics iff every rank ends holding every block; machine-
    /// model violations return the same [`SimError`]s as the lockstep
    /// simulator (see the module docs for the enforcement contract).
    pub fn run_bcast_with<S: Element>(
        &self,
        scratch: &mut EngineScratch<S>,
        elem_bytes: usize,
        cost: &dyn CostModel,
    ) -> Result<RunStats, SimError> {
        self.run_bcast_clocked(scratch, elem_bytes, cost, None)
    }

    /// [`Self::run_bcast_with`] with the cost plane attached: when `logp`
    /// is given, the executed trace is additionally clocked by a
    /// [`crate::sim::LogPClock`] (`RunStats::logp_time`).
    pub fn run_bcast_clocked<S: Element>(
        &self,
        scratch: &mut EngineScratch<S>,
        elem_bytes: usize,
        cost: &dyn CostModel,
        logp: Option<&LogPParams>,
    ) -> Result<RunStats, SimError> {
        let mut stats = RunStats { rounds: self.rounds, ..Default::default() };
        if self.p == 1 {
            stats.logp_time = logp.map(|_| 0.0);
            return Ok(stats);
        }
        let threads = scratch.delivery_threads.unwrap_or_else(configured_threads);
        let mut clock = logp.map(|p| LogPClock::new(*p));
        let mut trace: Vec<(usize, usize, usize)> = Vec::new();
        self.bcast_init(scratch);
        for j in 0..self.rounds {
            self.bcast_round(
                scratch,
                j,
                threads,
                elem_bytes,
                cost,
                &mut stats,
                if clock.is_some() { Some(&mut trace) } else { None },
            )?;
            if let Some(c) = clock.as_mut() {
                for &(from, to, bytes) in trace.iter() {
                    c.msg(from, to, bytes);
                }
                c.end_round();
                trace.clear();
            }
        }
        self.bcast_finish(scratch, &mut stats)?;
        stats.logp_time = clock.map(|c| c.total());
        Ok(stats)
    }

    /// Reset `scratch` to the broadcast start state: the root (rel 0)
    /// holds every block, everyone else nothing.
    fn bcast_init<S: Element>(&self, scratch: &mut EngineScratch<S>) {
        let p = self.p;
        let n = self.n;
        let words = (n + 63) / 64;
        let EngineScratch {
            holds, held, deliveries_b: deliveries, active, recv_mark, rank_bytes, ..
        } = scratch;
        reset(holds, p * words);
        for (w, word) in holds[..words].iter_mut().enumerate() {
            // The root (rel 0) starts with every block.
            *word = if (w + 1) * 64 <= n { u64::MAX } else { (1u64 << (n - w * 64)) - 1 };
        }
        reset(held, p);
        held[0] = n as u32;
        active.clear();
        active.reserve(p);
        active.push(0);
        reset(recv_mark, p);
        reset(rank_bytes, p);
        deliveries.clear();
    }

    /// The `(from, to)` pairs (absolute ranks) broadcast round `j` will
    /// use given the current worklist — the send scan of
    /// [`Self::bcast_round`] minus every mutation, so it can be called
    /// repeatedly (the traffic plane's port-ledger pre-check) before the
    /// round actually executes.
    fn bcast_ports<S: Element>(
        &self,
        scratch: &EngineScratch<S>,
        j: usize,
        out: &mut Vec<(usize, usize)>,
    ) {
        let p = self.p;
        if p == 1 {
            return;
        }
        let (k, delta) = self.round_params(j);
        let skip = self.sk.skip(k);
        for &rel32 in scratch.active.iter() {
            let rel = rel32 as usize;
            let t_rel = {
                let t = rel + skip;
                if t >= p {
                    t - p
                } else {
                    t
                }
            };
            if t_rel == 0 {
                continue;
            }
            if self.cap(self.table.send_raw(rel, k) as i64 + delta).is_none() {
                continue;
            }
            out.push((self.abs(rel), self.abs(t_rel)));
        }
    }

    /// Execute broadcast round `j` on `scratch` (must follow
    /// [`Self::bcast_init`] and rounds `0..j`): the shared round body of
    /// [`Self::run_bcast_with`] and [`EngineStep`]. `msgs` (when given)
    /// receives the round's executed `(from, to, bytes)` triples.
    #[allow(clippy::too_many_arguments)]
    fn bcast_round<S: Element>(
        &self,
        scratch: &mut EngineScratch<S>,
        j: usize,
        threads: usize,
        elem_bytes: usize,
        cost: &dyn CostModel,
        stats: &mut RunStats,
        mut msgs: Option<&mut Vec<(usize, usize, usize)>>,
    ) -> Result<(), SimError> {
        let p = self.p;
        let n = self.n;
        let words = (n + 63) / 64;
        let EngineScratch {
            holds, held, newly, deliveries_b: deliveries, active, recv_mark, rank_bytes, ..
        } = scratch;
        let (k, delta) = self.round_params(j);
        let skip = self.sk.skip(k);
        let stamp = (j + 1) as u64;
        let mut round_time = 0.0f64;
        let mut any = false;
        // Ranks activated during round j join the worklist for j+1:
        // scan only the prefix that was active at the round start.
        let live = active.len();
        for &rel32 in &active[..live] {
            let rel = rel32 as usize;
            let t_rel = {
                let t = rel + skip;
                if t >= p {
                    t - p
                } else {
                    t
                }
            };
            if t_rel == 0 {
                continue; // never send to the root (it has everything)
            }
            let b = match self.cap(self.table.send_raw(rel, k) as i64 + delta) {
                Some(b) => b,
                None => continue,
            };
            if holds[rel * words + b / 64] & (1u64 << (b % 64)) == 0 {
                panic!(
                    "engine: rank {} (rel {rel}) scheduled to send block {b} in round \
                     {j} but it has not been received — schedule violation",
                    self.abs(rel)
                );
            }
            let from = self.abs(rel);
            let to = self.abs(t_rel);
            // Receiver-side expectation cross-check (Conditions 1+2).
            let rb = match self.cap(self.table.recv_raw(t_rel, k) as i64 + delta) {
                Some(rb) => rb,
                None => {
                    return Err(SimError::UnexpectedMessage {
                        round: j,
                        to,
                        from,
                        expected: None,
                    })
                }
            };
            debug_assert_eq!(rb, b, "schedules disagree on the block (round {j})");
            // One-ported receive enforcement: one mark word per target.
            if recv_mark[t_rel] >> 32 == stamp {
                return Err(SimError::ReceivePortBusy {
                    round: j,
                    to,
                    first_from: (recv_mark[t_rel] & 0xffff_ffff) as usize,
                    second_from: from,
                });
            }
            recv_mark[t_rel] = stamp << 32 | from as u64;
            let bytes = self.geom.len(b) * elem_bytes;
            stats.messages += 1;
            stats.bytes += bytes;
            rank_bytes[from] += bytes;
            rank_bytes[to] += bytes;
            round_time = round_time.max(cost.msg_time(from, to, bytes));
            any = true;
            if let Some(out) = msgs.as_mut() {
                out.push((from, to, bytes));
            }
            deliveries.push((t_rel as u32, rb as u32));
        }
        // Deliver after the send scan: nothing received in round j is
        // visible to sends before round j+1 (lockstep order). The
        // targets are pairwise distinct (one-ported check above), so
        // a large queue can be applied in parallel shards.
        if threads > 1 && deliveries.len() >= PAR_DELIVERY_MIN {
            deliver_bcast_parallel(deliveries, newly, holds, held, active, words, threads);
        } else {
            for &(to_rel, b) in deliveries.iter() {
                let (to_rel, b) = (to_rel as usize, b as usize);
                let w = to_rel * words + b / 64;
                let bit = 1u64 << (b % 64);
                if holds[w] & bit == 0 {
                    holds[w] |= bit;
                    if held[to_rel] == 0 {
                        active.push(to_rel as u32);
                    }
                    held[to_rel] += 1;
                }
            }
        }
        deliveries.clear();
        if any {
            stats.active_rounds += 1;
            stats.time += round_time;
        }
        Ok(())
    }

    /// Close a broadcast run: fold `max_rank_bytes` and run the deferred
    /// missing-message check.
    fn bcast_finish<S: Element>(
        &self,
        scratch: &EngineScratch<S>,
        stats: &mut RunStats,
    ) -> Result<(), SimError> {
        let words = (self.n + 63) / 64;
        stats.max_rank_bytes = scratch.rank_bytes.iter().copied().max().unwrap_or(0);
        if let Some(err) = self.find_missing_bcast(&scratch.holds, words, &scratch.held) {
            return Err(err);
        }
        Ok(())
    }

    /// Deferred missing-message check for broadcast: if any rank ended
    /// without all `n` blocks, reconstruct the earliest round in which an
    /// expected block failed to arrive (best effort on broken schedules —
    /// the lockstep simulator, which aborts mid-run, stays authoritative).
    ///
    /// The completion test compares each rank's possession words against
    /// the root's (the root holds every block from init and never
    /// changes), i.e. one `memcmp` per rank rather than a bit test per
    /// `(round, rank)` probe. The reconstruction scan then visits only
    /// the ranks that ended incomplete: a `(j, rel)` hit requires `rel`'s
    /// possession bit for the round's block to be clear, so complete
    /// ranks can never anchor one — restricting the inner loop to the
    /// ascending incomplete list preserves the lexicographically
    /// earliest `(round, rank)` error exactly.
    fn find_missing_bcast(&self, holds: &[u64], words: usize, held: &[u32]) -> Option<SimError> {
        let template = &holds[..words];
        if words == 0 || holds.chunks_exact(words).all(|row| row == template) {
            return None;
        }
        let incomplete: Vec<usize> =
            (1..self.p).filter(|&rel| held[rel] as usize != self.n).collect();
        for j in 0..self.rounds {
            let (k, delta) = self.round_params(j);
            let skip = self.sk.skip(k);
            for &rel in &incomplete {
                let rval = self.table.recv_raw(rel, k) as i64 + delta;
                let b = match self.cap(rval) {
                    Some(b) => b,
                    None => continue,
                };
                if holds[rel * words + b / 64] & (1u64 << (b % 64)) == 0 {
                    let from_rel = {
                        let t = rel + self.p - skip;
                        if t >= self.p {
                            t - self.p
                        } else {
                            t
                        }
                    };
                    return Some(SimError::MissingMessage {
                        round: j,
                        rank: self.abs(rel),
                        expected_from: self.abs(from_rel),
                    });
                }
            }
        }
        unreachable!("engine: incomplete broadcast without a reconstructable missing message")
    }

    // ------------------------------------------------------------------
    // Reduction (reversed schedules, Observation 1.3)
    // ------------------------------------------------------------------

    /// Activity profiles (closed form, O(log p) per rank): a rank sends
    /// in reversed round `jr` iff its *receive* row is non-negative at
    /// forward round `i = rounds-1-jr`, so its last reversed send passes
    /// when `i` drops below its first forward send round. A rank expects
    /// a receive iff its *send* row is non-negative and its forward
    /// to-processor is not the root. Computed once per engine; the
    /// worklist is ordered by a counting sort over first-send rounds —
    /// O(p + rounds), replacing the old per-run O(p log p) sort.
    fn reduce_profile(&self) -> &ReduceProfile {
        self.reduce_profile.get_or_init(|| {
            let p = self.p;
            let mut first_send = vec![usize::MAX; p];
            let mut expect_recv = vec![0u32; p];
            for rel in 0..p {
                if rel != 0 {
                    let (_, first) = self.row_occupancy(self.table.recv_row(rel), |_| true);
                    first_send[rel] = first;
                }
                let (cnt, _) = self.row_occupancy(self.table.send_row(rel), |k| {
                    let t = rel + self.sk.skip(k);
                    (if t >= p { t - p } else { t }) != 0
                });
                expect_recv[rel] = cnt as u32;
            }
            // Counting sort: bucket by first_send (all values < rounds),
            // prefix-sum to cursors, place ranks ascending — stable, so
            // the order matches the old stable sort_by_key exactly.
            let mut cursors = vec![0u32; self.rounds + 1];
            for rel in 1..p {
                if first_send[rel] != usize::MAX {
                    cursors[first_send[rel] + 1] += 1;
                }
            }
            for i in 1..cursors.len() {
                cursors[i] += cursors[i - 1];
            }
            let total = *cursors.last().unwrap() as usize;
            let mut active = vec![0u32; total];
            for rel in 1..p {
                let f = first_send[rel];
                if f != usize::MAX {
                    active[cursors[f] as usize] = rel as u32;
                    cursors[f] += 1;
                }
            }
            ReduceProfile { first_send, expect_recv, active }
        })
    }

    /// Simulate the full rooted reduction with a throwaway scratch. See
    /// [`Self::run_reduce_with`].
    pub fn run_reduce<T: Element>(
        &self,
        inputs: &[Vec<T>],
        op: &dyn ReduceOp<T>,
        elem_bytes: usize,
        cost: &dyn CostModel,
    ) -> Result<(RunStats, Vec<T>), SimError> {
        self.run_reduce_with(&mut EngineScratch::new(), inputs, op, elem_bytes, cost)
    }

    /// Simulate the full rooted reduction, reusing `scratch`
    /// (allocation-free after its first use): `inputs[r]` is *absolute*
    /// rank `r`'s `m`-element contribution; returns the run statistics
    /// and the root's fully reduced buffer.
    ///
    /// All partials live in one `(rank, block)`-indexed arena; a send
    /// stages the sender's arena range through a reused per-round scratch
    /// (the lockstep clone-at-send, minus the per-message allocation) and
    /// the receiver combines in place with ⊕ — sharded across scoped
    /// threads when the round's delivery queue is large (distinct
    /// destinations ⇒ disjoint arena rows ⇒ bit-identical results).
    pub fn run_reduce_with<T: Element>(
        &self,
        scratch: &mut EngineScratch<T>,
        inputs: &[Vec<T>],
        op: &dyn ReduceOp<T>,
        elem_bytes: usize,
        cost: &dyn CostModel,
    ) -> Result<(RunStats, Vec<T>), SimError> {
        self.run_reduce_clocked(scratch, inputs, op, elem_bytes, cost, None)
    }

    /// [`Self::run_reduce_with`] with the cost plane attached: when
    /// `logp` is given, the executed trace is additionally clocked by a
    /// [`crate::sim::LogPClock`] (`RunStats::logp_time`).
    pub fn run_reduce_clocked<T: Element>(
        &self,
        scratch: &mut EngineScratch<T>,
        inputs: &[Vec<T>],
        op: &dyn ReduceOp<T>,
        elem_bytes: usize,
        cost: &dyn CostModel,
        logp: Option<&LogPParams>,
    ) -> Result<(RunStats, Vec<T>), SimError> {
        let p = self.p;
        let m = self.geom.m;
        assert_eq!(inputs.len(), p, "reduce needs one contribution per rank");
        let mut stats = RunStats { rounds: self.rounds, ..Default::default() };
        if p == 1 {
            assert_eq!(inputs[self.root].len(), m);
            stats.logp_time = logp.map(|_| 0.0);
            return Ok((stats, inputs[self.root].clone()));
        }
        let threads = scratch.delivery_threads.unwrap_or_else(configured_threads);
        let mut clock = logp.map(|p| LogPClock::new(*p));
        let mut trace: Vec<(usize, usize, usize)> = Vec::new();
        self.reduce_init(scratch, inputs);
        for jr in 0..self.rounds {
            self.reduce_round(
                scratch,
                jr,
                threads,
                op,
                elem_bytes,
                cost,
                &mut stats,
                if clock.is_some() { Some(&mut trace) } else { None },
            )?;
            if let Some(c) = clock.as_mut() {
                for &(from, to, bytes) in trace.iter() {
                    c.msg(from, to, bytes);
                }
                c.end_round();
                trace.clear();
            }
        }
        self.reduce_finish(scratch, &mut stats)?;
        stats.logp_time = clock.map(|c| c.total());
        Ok((stats, self.reduce_result(scratch)))
    }

    /// Reset `scratch` to the reduction start state: every rank's
    /// contribution in the `(rank, block)`-indexed arena, the sender
    /// worklist in profile order.
    fn reduce_init<T: Element>(&self, scratch: &mut EngineScratch<T>, inputs: &[Vec<T>]) {
        let p = self.p;
        let m = self.geom.m;
        assert_eq!(inputs.len(), p, "reduce needs one contribution per rank");
        let profile = self.reduce_profile();
        let EngineScratch {
            active, recv_mark, recv_count, rank_bytes, arena, stage,
            deliveries_r: deliveries, ..
        } = scratch;
        // The payload arena: rel r's partial of block b lives at
        // r*m + geom.range(b).
        arena.clear();
        arena.reserve(p * m);
        for rel in 0..p {
            let inp = &inputs[self.abs(rel)];
            assert_eq!(inp.len(), m, "reduce contributions must have {m} elements");
            arena.extend_from_slice(inp);
        }
        // Active senders (profile order: ascending first forward send
        // round); the tail deactivates first as reversed time sweeps `i`
        // downwards.
        active.clear();
        active.extend_from_slice(&profile.active);
        reset(recv_mark, p);
        reset(recv_count, p);
        reset(rank_bytes, p);
        stage.clear();
        deliveries.clear();
    }

    /// Drop worklist-tail ranks whose last reversed send has passed by
    /// reversed round `jr` — idempotent for a fixed `jr`, so both the
    /// port pre-scan and the round execution may apply it.
    fn reduce_prune(&self, active: &mut Vec<u32>, first_send: &[usize], jr: usize) {
        let i = self.rounds - 1 - jr;
        while let Some(&last) = active.last() {
            if first_send[last as usize] > i {
                active.pop();
            } else {
                break;
            }
        }
    }

    /// The `(from, to)` pairs (absolute ranks) reversed round `jr` will
    /// use — the send scan of [`Self::reduce_round`] minus every
    /// state change except the (idempotent) worklist-tail prune.
    fn reduce_ports<T: Element>(
        &self,
        scratch: &mut EngineScratch<T>,
        jr: usize,
        out: &mut Vec<(usize, usize)>,
    ) {
        let p = self.p;
        if p == 1 {
            return;
        }
        let profile = self.reduce_profile();
        self.reduce_prune(&mut scratch.active, &profile.first_send, jr);
        let i = self.rounds - 1 - jr;
        let (k, delta) = self.round_params(i);
        let skip = self.sk.skip(k);
        for &rel32 in scratch.active.iter() {
            let rel = rel32 as usize;
            if self.cap(self.table.recv_raw(rel, k) as i64 + delta).is_none() {
                continue;
            }
            let to_rel = {
                let t = rel + p - skip;
                if t >= p {
                    t - p
                } else {
                    t
                }
            };
            out.push((self.abs(rel), self.abs(to_rel)));
        }
    }

    /// Execute reversed round `jr` on `scratch` (must follow
    /// [`Self::reduce_init`] and rounds `0..jr`): the shared round body
    /// of [`Self::run_reduce_with`] and [`EngineStep`]. `msgs` (when
    /// given) receives the round's executed `(from, to, bytes)` triples.
    #[allow(clippy::too_many_arguments)]
    fn reduce_round<T: Element>(
        &self,
        scratch: &mut EngineScratch<T>,
        jr: usize,
        threads: usize,
        op: &dyn ReduceOp<T>,
        elem_bytes: usize,
        cost: &dyn CostModel,
        stats: &mut RunStats,
        mut msgs: Option<&mut Vec<(usize, usize, usize)>>,
    ) -> Result<(), SimError> {
        let p = self.p;
        let m = self.geom.m;
        let profile = self.reduce_profile();
        let EngineScratch {
            active, recv_mark, recv_count, rank_bytes, arena, stage,
            deliveries_r: deliveries, ..
        } = scratch;
        self.reduce_prune(active, &profile.first_send, jr);
        let i = self.rounds - 1 - jr;
        let (k, delta) = self.round_params(i);
        let skip = self.sk.skip(k);
        let stamp = (jr + 1) as u64;
        let mut round_time = 0.0f64;
        let mut any = false;
        for &rel32 in active.iter() {
            let rel = rel32 as usize;
            // Reversal of the broadcast receive: forward our partial
            // of recvblock[k] to the from-processor.
            let b = match self.cap(self.table.recv_raw(rel, k) as i64 + delta) {
                Some(b) => b,
                None => continue,
            };
            let to_rel = {
                let t = rel + p - skip;
                if t >= p {
                    t - p
                } else {
                    t
                }
            };
            let from = self.abs(rel);
            let to = self.abs(to_rel);
            // Receiver-side cross-check (reversed Condition 2).
            let rb = match self.cap(self.table.send_raw(to_rel, k) as i64 + delta) {
                Some(rb) => rb,
                None => {
                    return Err(SimError::UnexpectedMessage {
                        round: jr,
                        to,
                        from,
                        expected: None,
                    })
                }
            };
            debug_assert_eq!(rb, b, "schedules disagree on the block (reversed round {jr})");
            if recv_mark[to_rel] >> 32 == stamp {
                return Err(SimError::ReceivePortBusy {
                    round: jr,
                    to,
                    first_from: (recv_mark[to_rel] & 0xffff_ffff) as usize,
                    second_from: from,
                });
            }
            recv_mark[to_rel] = stamp << 32 | from as u64;
            recv_count[to_rel] += 1;
            let (off, len) = self.geom.range(b);
            // "Send": stage the sender's arena range in the round
            // scratch so this round's combines see round-start state.
            // The queued delivery is 16 bytes — the combine length is
            // re-derived from the geometry at application time.
            let s_off = stage.len();
            stage.extend_from_slice(&arena[rel * m + off..rel * m + off + len]);
            deliveries.push((to_rel as u32, rb as u32, s_off));
            let bytes = len * elem_bytes;
            stats.messages += 1;
            stats.bytes += bytes;
            rank_bytes[from] += bytes;
            rank_bytes[to] += bytes;
            round_time = round_time.max(cost.msg_time(from, to, bytes));
            any = true;
            if let Some(out) = msgs.as_mut() {
                out.push((from, to, bytes));
            }
        }
        if threads > 1 && deliveries.len() >= PAR_DELIVERY_MIN {
            deliver_reduce_parallel(deliveries, arena, stage, self.geom, m, op, threads);
        } else {
            for &(dst_rel, rb, s_off) in deliveries.iter() {
                let (dst_rel, rb) = (dst_rel as usize, rb as usize);
                let (d_off, d_len) = self.geom.range(rb);
                let dst = &mut arena[dst_rel * m + d_off..dst_rel * m + d_off + d_len];
                op.combine(dst, &stage[s_off..s_off + d_len]);
            }
        }
        deliveries.clear();
        stage.clear();
        if any {
            stats.active_rounds += 1;
            stats.time += round_time;
        }
        Ok(())
    }

    /// Close a reduction run: fold `max_rank_bytes` and run the deferred
    /// receive-count check.
    fn reduce_finish<T: Element>(
        &self,
        scratch: &EngineScratch<T>,
        stats: &mut RunStats,
    ) -> Result<(), SimError> {
        stats.max_rank_bytes = scratch.rank_bytes.iter().copied().max().unwrap_or(0);
        let profile = self.reduce_profile();
        if let Some(err) = self.find_missing_reduce(&scratch.recv_count, &profile.expect_recv) {
            return Err(err);
        }
        Ok(())
    }

    /// The root's fully reduced buffer — rel 0's arena row (copied out so
    /// the arena stays reusable scratch).
    fn reduce_result<T: Element>(&self, scratch: &EngineScratch<T>) -> Vec<T> {
        scratch.arena[..self.geom.m].to_vec()
    }

    /// Deferred missing-message check for reduction: compare actual
    /// against closed-form expected receive counts (one slice compare —
    /// `memcmp` — on the happy path); on mismatch, reconstruct the
    /// earliest reversed round whose expected message had no sender.
    ///
    /// The reconstruction scan visits only the ranks whose counts
    /// diverged: a `(jr, rel)` hit means `rel` expected a receive (send
    /// row non-negative, to-processor not the root) that its unique
    /// per-round sender `rel + skip` never sent — and since a rank's
    /// receives in a reversed round can only come from that one sender,
    /// every hit leaves `rel`'s actual count short of its expectation.
    /// Iterating the divergent ranks in ascending order inside the
    /// round-outer loop therefore preserves the lexicographically
    /// earliest `(round, rank)` error exactly.
    fn find_missing_reduce(&self, recv_count: &[u32], expect: &[u32]) -> Option<SimError> {
        if recv_count == expect {
            return None;
        }
        let p = self.p;
        let divergent: Vec<usize> =
            (0..p).filter(|&rel| recv_count[rel] != expect[rel]).collect();
        for jr in 0..self.rounds {
            let i = self.rounds - 1 - jr;
            let (k, delta) = self.round_params(i);
            let skip = self.sk.skip(k);
            for &rel in &divergent {
                let sender = {
                    let t = rel + skip;
                    if t >= p {
                        t - p
                    } else {
                        t
                    }
                };
                if sender == 0 {
                    continue; // the root never sends in a reduction
                }
                if (self.table.send_raw(rel, k) as i64 + delta) < 0 {
                    continue; // rel expects nothing here
                }
                if (self.table.recv_raw(sender, k) as i64 + delta) < 0 {
                    return Some(SimError::MissingMessage {
                        round: jr,
                        rank: self.abs(rel),
                        expected_from: self.abs(sender),
                    });
                }
            }
        }
        unreachable!("engine: receive-count mismatch without a reconstructable missing message")
    }
}

/// A resumable, round-steppable engine run — the per-round counterpart
/// of [`CirculantEngine::run_bcast_with`] /
/// [`CirculantEngine::run_reduce_with`], built from the *same* shared
/// round bodies, so a stepped run is bit-identical (payloads, statistics
/// and error values alike) to a blocking one. This is what lets the
/// traffic plane ([`crate::comm::traffic::TrafficEngine`]) drive many
/// engines in lockstep machine rounds, interleaving their rounds with
/// other collectives under the cross-operation port ledger.
///
/// An `EngineStep` owns its [`CirculantEngine`] (construction is O(1)
/// past the shared `Arc<ScheduleTable>`) and an [`EngineScratch`] —
/// typically borrowed from a [`ScratchPool`] and returned by
/// [`EngineStep::finish`] so overlapping operations reuse run scratch
/// instead of allocating per operation.
pub struct EngineStep<T: Element> {
    eng: CirculantEngine,
    scratch: EngineScratch<T>,
    /// `Some(op)` for a reduction, `None` for a broadcast.
    op: Option<Arc<dyn ReduceOp<T>>>,
    elem_bytes: usize,
    threads: usize,
    j: usize,
    stats: RunStats,
}

impl<T: Element> EngineStep<T> {
    /// Begin a steppable broadcast run.
    pub fn bcast(eng: CirculantEngine, mut scratch: EngineScratch<T>, elem_bytes: usize) -> Self {
        let threads = scratch.delivery_threads.unwrap_or_else(configured_threads);
        let stats = RunStats { rounds: eng.rounds, ..Default::default() };
        eng.bcast_init(&mut scratch);
        EngineStep { eng, scratch, op: None, elem_bytes, threads, j: 0, stats }
    }

    /// Begin a steppable reduction run: `inputs[r]` is absolute rank
    /// `r`'s contribution, copied into the arena up front.
    pub fn reduce(
        eng: CirculantEngine,
        mut scratch: EngineScratch<T>,
        inputs: &[Vec<T>],
        op: Arc<dyn ReduceOp<T>>,
        elem_bytes: usize,
    ) -> Self {
        let threads = scratch.delivery_threads.unwrap_or_else(configured_threads);
        let stats = RunStats { rounds: eng.rounds, ..Default::default() };
        eng.reduce_init(&mut scratch, inputs);
        EngineStep { eng, scratch, op: Some(op), elem_bytes, threads, j: 0, stats }
    }

    #[inline]
    pub fn rounds(&self) -> usize {
        self.eng.rounds
    }

    /// The round the next [`EngineStep::step`] will execute.
    #[inline]
    pub fn next_round(&self) -> usize {
        self.j
    }

    #[inline]
    pub fn is_done(&self) -> bool {
        self.j >= self.eng.rounds
    }

    /// The `(from, to)` pairs (absolute ranks) the next round will use —
    /// callable any number of times before the round executes (the
    /// port-ledger pre-check; see [`CirculantEngine`]'s `*_ports` scans).
    pub fn ports(&mut self, out: &mut Vec<(usize, usize)>) {
        if self.is_done() {
            return;
        }
        match &self.op {
            None => self.eng.bcast_ports(&self.scratch, self.j, out),
            Some(_) => self.eng.reduce_ports(&mut self.scratch, self.j, out),
        }
    }

    /// Execute the next round; `msgs` (when given) receives the round's
    /// executed `(from, to, bytes)` triples. On error the run is
    /// poisoned exactly where a blocking run would have aborted.
    pub fn step(
        &mut self,
        cost: &dyn CostModel,
        msgs: Option<&mut Vec<(usize, usize, usize)>>,
    ) -> Result<(), SimError> {
        assert!(!self.is_done(), "step called on a completed run");
        let op = self.op.clone();
        let res = match op {
            None => self.eng.bcast_round(
                &mut self.scratch,
                self.j,
                self.threads,
                self.elem_bytes,
                cost,
                &mut self.stats,
                msgs,
            ),
            Some(op) => self.eng.reduce_round(
                &mut self.scratch,
                self.j,
                self.threads,
                op.as_ref(),
                self.elem_bytes,
                cost,
                &mut self.stats,
                msgs,
            ),
        };
        if res.is_ok() {
            self.j += 1;
        }
        res
    }

    /// Close the run (all rounds must be stepped): the deferred
    /// completion checks, final statistics and — for a reduction — the
    /// root's reduced buffer, plus the scratch back for pooling.
    #[allow(clippy::type_complexity)]
    pub fn finish(mut self) -> (Result<(RunStats, Option<Vec<T>>), SimError>, EngineScratch<T>) {
        assert!(self.is_done(), "finish called with rounds remaining");
        let res = match &self.op {
            None => self.eng.bcast_finish(&self.scratch, &mut self.stats),
            Some(_) => self.eng.reduce_finish(&self.scratch, &mut self.stats),
        };
        let res = res.map(|()| {
            let buf = self.op.as_ref().map(|_| self.eng.reduce_result(&self.scratch));
            (self.stats.clone(), buf)
        });
        (res, self.scratch)
    }
}

/// A shared pool of [`EngineScratch`] values, type-erased so one pool
/// serves a heterogeneous batch of operations: [`ScratchPool::take`]
/// returns a pooled scratch of the requested element type when one is
/// free (allocation-free past its first use), else a fresh one; finished
/// operations [`ScratchPool::put`] their scratch back.
#[derive(Default)]
pub struct ScratchPool {
    free: Mutex<Vec<Box<dyn Any + Send>>>,
}

impl ScratchPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// A scratch of element type `T`: pooled if available, fresh
    /// otherwise. Callers should re-set `delivery_threads` — a pooled
    /// scratch keeps its previous override.
    pub fn take<T: Element>(&self) -> EngineScratch<T> {
        let mut free = self.free.lock().unwrap();
        if let Some(pos) = free.iter().position(|b| b.is::<EngineScratch<T>>()) {
            return *free.swap_remove(pos).downcast().expect("position() type-checked");
        }
        EngineScratch::new()
    }

    /// Return a scratch for reuse.
    pub fn put<T: Element>(&self, scratch: EngineScratch<T>) {
        self.free.lock().unwrap().push(Box::new(scratch));
    }

    /// Number of pooled (idle) scratches.
    pub fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

/// Sharded broadcast delivery: set the `(rank, block)` bits and record
/// first-block activations in `newly` (delivery-indexed), then append
/// the activations serially in delivery order — bit-identical state and
/// worklist order to the serial loop.
fn deliver_bcast_parallel(
    deliveries: &[(u32, u32)],
    newly: &mut Vec<u8>,
    holds: &mut [u64],
    held: &mut [u32],
    active: &mut Vec<u32>,
    words: usize,
    threads: usize,
) {
    reset(newly, deliveries.len());
    let chunk = (deliveries.len() + threads - 1) / threads;
    let holds_ptr = SendPtr(holds.as_mut_ptr());
    let held_ptr = SendPtr(held.as_mut_ptr());
    std::thread::scope(|s| {
        for (dchunk, nchunk) in deliveries.chunks(chunk).zip(newly.chunks_mut(chunk)) {
            s.spawn(move || {
                for (&(to_rel, b), flag) in dchunk.iter().zip(nchunk) {
                    let (to_rel, b) = (to_rel as usize, b as usize);
                    // SAFETY: delivery targets within one round are
                    // pairwise distinct (one-ported check), and both
                    // `holds` (rank-major words) and `held` are indexed
                    // by target rank — every word touched here is owned
                    // by exactly one delivery, i.e. one shard.
                    unsafe {
                        let w = holds_ptr.0.add(to_rel * words + b / 64);
                        let bit = 1u64 << (b % 64);
                        if *w & bit == 0 {
                            *w |= bit;
                            let h = held_ptr.0.add(to_rel);
                            *flag = u8::from(*h == 0);
                            *h += 1;
                        }
                    }
                }
            });
        }
    });
    for (i, &(to_rel, _)) in deliveries.iter().enumerate() {
        if newly[i] != 0 {
            active.push(to_rel);
        }
    }
}

/// Sharded reduction delivery: each delivery ⊕-combines a staged payload
/// into its destination's arena row. Distinct destinations per round ⇒
/// disjoint rows ⇒ the shards commute and the result is bit-identical
/// (each row is combined by exactly one delivery).
fn deliver_reduce_parallel<T: Element>(
    deliveries: &[(u32, u32, usize)],
    arena: &mut [T],
    stage: &[T],
    geom: BlockGeometry,
    m: usize,
    op: &dyn ReduceOp<T>,
    threads: usize,
) {
    let chunk = (deliveries.len() + threads - 1) / threads;
    let arena_ptr = SendPtr(arena.as_mut_ptr());
    std::thread::scope(|s| {
        for dchunk in deliveries.chunks(chunk) {
            s.spawn(move || {
                for &(dst_rel, rb, s_off) in dchunk {
                    let (dst_rel, rb) = (dst_rel as usize, rb as usize);
                    let (d_off, d_len) = geom.range(rb);
                    // SAFETY: destination ranks within one round are
                    // pairwise distinct (one-ported check), so the
                    // `dst_rel*m + ..` ranges of concurrent shards are
                    // disjoint; `stage` is only read.
                    let dst = unsafe {
                        std::slice::from_raw_parts_mut(arena_ptr.0.add(dst_rel * m + d_off), d_len)
                    };
                    op.combine(dst, &stage[s_off..s_off + d_len]);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::bcast::build_bcast_procs;
    use crate::collectives::common::SumOp;
    use crate::collectives::reduce::build_reduce_procs;
    use crate::sim::cost::{HierarchicalCost, UnitCost};
    use crate::sim::network::Network;

    fn stats_eq(a: &RunStats, b: &RunStats, ctx: &str) {
        assert_eq!(a.rounds, b.rounds, "{ctx}: rounds");
        assert_eq!(a.active_rounds, b.active_rounds, "{ctx}: active_rounds");
        assert_eq!(a.messages, b.messages, "{ctx}: messages");
        assert_eq!(a.bytes, b.bytes, "{ctx}: bytes");
        assert_eq!(a.max_rank_bytes, b.max_rank_bytes, "{ctx}: max_rank_bytes");
        assert!((a.time - b.time).abs() < 1e-12, "{ctx}: time {} vs {}", a.time, b.time);
    }

    #[test]
    fn bcast_stats_match_lockstep_grid() {
        // The hierarchical cost model distinguishes absolute ranks, so a
        // broken rel->abs mapping in the engine's cost accounting shows.
        let cost = HierarchicalCost::vega(4);
        for p in [1usize, 2, 3, 5, 9, 16, 17, 18, 33] {
            let sk = Arc::new(Skips::new(p));
            let src = ScheduleSource::Direct(&sk);
            let table = Arc::new(ScheduleTable::build(&sk));
            for n in [1usize, 2, 5, 8] {
                for root in [0, p / 2] {
                    for m in [3 * n + 1, n.saturating_sub(2)] {
                        let geom = BlockGeometry::new(m, n);
                        let data: Vec<u32> = (0..m as u32).collect();
                        let mut procs = build_bcast_procs(&src, root, geom, &data);
                        let lstats = Network::new(p).run(&mut procs, 4, &cost).unwrap();
                        assert!(procs.iter().all(|pr| pr.complete()));
                        let eng = CirculantEngine::new(table.clone(), root, geom);
                        let estats = eng.run_bcast(4, &cost).unwrap();
                        stats_eq(
                            &estats,
                            &lstats,
                            &format!("bcast p={p} n={n} root={root} m={m}"),
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn reduce_matches_lockstep_grid() {
        let cost = HierarchicalCost::vega(2);
        for p in [1usize, 2, 3, 5, 9, 16, 17, 18, 33] {
            let sk = Arc::new(Skips::new(p));
            let src = ScheduleSource::Direct(&sk);
            let table = Arc::new(ScheduleTable::build(&sk));
            for n in [1usize, 2, 5] {
                for root in [0, p - 1] {
                    let m = 4 * n + 3;
                    let geom = BlockGeometry::new(m, n);
                    let inputs: Vec<Vec<i64>> = (0..p)
                        .map(|r| (0..m).map(|i| ((r + 1) * (i + 3)) as i64 % 257).collect())
                        .collect();
                    let op = Arc::new(SumOp);
                    let mut procs =
                        build_reduce_procs(&src, root, geom, &inputs, op.clone());
                    let lstats = Network::new(p).run(&mut procs, 8, &cost).unwrap();
                    let lbuf = procs.into_iter().nth(root).unwrap().into_buffer();
                    let eng = CirculantEngine::new(table.clone(), root, geom);
                    let (estats, ebuf) = eng.run_reduce(&inputs, &SumOp, 8, &cost).unwrap();
                    stats_eq(&estats, &lstats, &format!("reduce p={p} n={n} root={root}"));
                    assert_eq!(ebuf, lbuf, "reduce p={p} n={n} root={root}");
                }
            }
        }
    }

    #[test]
    fn zero_length_payloads_still_flow() {
        // m = 0: every block is empty; the schedule still runs and every
        // "send" counts as a message, exactly like the lockstep procs.
        let sk = Arc::new(Skips::new(17));
        let src = ScheduleSource::Direct(&sk);
        let geom = BlockGeometry::new(0, 4);
        let data: Vec<u32> = Vec::new();
        let mut procs = build_bcast_procs(&src, 2, geom, &data);
        let lstats = Network::new(17).run(&mut procs, 4, &UnitCost).unwrap();
        let eng = CirculantEngine::from_skips(&sk, 2, geom);
        let estats = eng.run_bcast(4, &UnitCost).unwrap();
        stats_eq(&estats, &lstats, "empty payload");
        assert!(estats.messages > 0);
        assert_eq!(estats.bytes, 0);
    }

    #[test]
    fn scratch_reuse_is_stable_across_runs_and_engines() {
        // One scratch across different (p, root, n, collective): every
        // rerun must produce identical stats/payloads to a fresh run.
        let mut scratch = EngineScratch::<i64>::new();
        for p in [5usize, 17, 33] {
            let sk = Arc::new(Skips::new(p));
            let table = Arc::new(ScheduleTable::build(&sk));
            for (root, n, m) in [(0usize, 3usize, 10usize), (p - 1, 5, 21)] {
                let geom = BlockGeometry::new(m, n);
                let eng = CirculantEngine::new(table.clone(), root, geom);
                let fresh = eng.run_bcast(4, &UnitCost).unwrap();
                for _ in 0..3 {
                    let reused = eng.run_bcast_with(&mut scratch, 4, &UnitCost).unwrap();
                    stats_eq(&reused, &fresh, &format!("bcast reuse p={p} root={root}"));
                }
                let inputs: Vec<Vec<i64>> =
                    (0..p).map(|r| (0..m).map(|i| (r * 31 + i) as i64).collect()).collect();
                let (fs, fb) = eng.run_reduce(&inputs, &SumOp, 8, &UnitCost).unwrap();
                for _ in 0..3 {
                    let (rs, rb) = eng
                        .run_reduce_with(&mut scratch, &inputs, &SumOp, 8, &UnitCost)
                        .unwrap();
                    stats_eq(&rs, &fs, &format!("reduce reuse p={p} root={root}"));
                    assert_eq!(rb, fb);
                }
            }
        }
    }

    #[test]
    fn sharded_deliveries_match_serial() {
        // Large enough that late rounds cross PAR_DELIVERY_MIN: the
        // sharded and serial delivery paths must agree bit for bit.
        let p = (1usize << 14) + 5;
        let sk = Arc::new(Skips::new(p));
        let table = Arc::new(ScheduleTable::build(&sk));
        let geom = BlockGeometry::new(8, 4);
        let eng = CirculantEngine::new(table.clone(), 3, geom);
        let mut serial = EngineScratch::<i64>::new();
        serial.delivery_threads = Some(1);
        let mut sharded = EngineScratch::<i64>::new();
        sharded.delivery_threads = Some(8);
        let a = eng.run_bcast_with(&mut serial, 4, &UnitCost).unwrap();
        let b = eng.run_bcast_with(&mut sharded, 4, &UnitCost).unwrap();
        stats_eq(&a, &b, "sharded bcast");

        let inputs: Vec<Vec<i64>> = (0..p).map(|r| vec![r as i64 % 97; 8]).collect();
        let (ra, ba) = eng
            .run_reduce_with(&mut serial, &inputs, &SumOp, 8, &UnitCost)
            .unwrap();
        let (rb, bb) = eng
            .run_reduce_with(&mut sharded, &inputs, &SumOp, 8, &UnitCost)
            .unwrap();
        stats_eq(&ra, &rb, "sharded reduce");
        assert_eq!(ba, bb, "sharded reduce payload");
    }

    #[test]
    fn corrupted_recv_row_is_unexpected_message() {
        let sk = Arc::new(Skips::new(17));
        let mut table = ScheduleTable::build(&sk);
        // Rank rel 1 receives its baseblock in slot 0; deny it.
        let q = table.q();
        table.recv_row_mut(1)[0] = -(q as i64) as i8;
        let eng = CirculantEngine::new(Arc::new(table), 0, BlockGeometry::new(34, 2));
        match eng.run_bcast(4, &UnitCost) {
            Err(SimError::UnexpectedMessage { expected: None, .. }) => {}
            other => panic!("want UnexpectedMessage, got {other:?}"),
        }
    }

    #[test]
    fn corrupted_send_row_is_missing_message() {
        let sk = Arc::new(Skips::new(9));
        let mut table = ScheduleTable::build(&sk);
        // The root never offers slot 0's block: its first receiver starves
        // (and, downstream, more ranks stay incomplete).
        let q = table.q();
        table.send_row_mut(0)[0] = -(q as i64) as i8;
        let eng = CirculantEngine::new(Arc::new(table), 0, BlockGeometry::new(18, 2));
        match eng.run_bcast(4, &UnitCost) {
            Err(SimError::MissingMessage { .. }) => {}
            other => panic!("want MissingMessage, got {other:?}"),
        }
    }

    #[test]
    fn stepped_runs_match_blocking_runs() {
        // EngineStep shares the blocking run's round bodies; this pins
        // that a round-by-round drive (with port pre-scans in between)
        // yields bit-identical stats, payloads and port predictions.
        let pool = ScratchPool::new();
        for p in [1usize, 2, 5, 17, 33] {
            let sk = Arc::new(Skips::new(p));
            let table = Arc::new(ScheduleTable::build(&sk));
            for (root, n, m) in [(0usize, 1usize, 5usize), (p - 1, 4, 18)] {
                let geom = BlockGeometry::new(m, n);
                let eng = CirculantEngine::new(table.clone(), root, geom);
                let blocking = eng.run_bcast(4, &UnitCost).unwrap();

                let eng2 = CirculantEngine::new(table.clone(), root, geom);
                let mut step = EngineStep::<i64>::bcast(eng2, pool.take(), 4);
                let mut ports = Vec::new();
                let mut msgs = Vec::new();
                while !step.is_done() {
                    ports.clear();
                    step.ports(&mut ports);
                    ports.sort_unstable();
                    msgs.clear();
                    step.step(&UnitCost, Some(&mut msgs)).unwrap();
                    let mut sent: Vec<(usize, usize)> =
                        msgs.iter().map(|&(f, t, _)| (f, t)).collect();
                    sent.sort_unstable();
                    assert_eq!(ports, sent, "bcast ports predict sends p={p} root={root}");
                }
                let (res, scratch) = step.finish();
                pool.put(scratch);
                let (sstats, sbuf) = res.unwrap();
                stats_eq(&sstats, &blocking, &format!("stepped bcast p={p} root={root}"));
                assert!(sbuf.is_none());

                let inputs: Vec<Vec<i64>> =
                    (0..p).map(|r| (0..m).map(|i| (r * 13 + i) as i64).collect()).collect();
                let (bstats, bbuf) = eng.run_reduce(&inputs, &SumOp, 8, &UnitCost).unwrap();
                let mut step = EngineStep::<i64>::reduce(
                    CirculantEngine::new(table.clone(), root, geom),
                    pool.take(),
                    &inputs,
                    Arc::new(SumOp),
                    8,
                );
                while !step.is_done() {
                    ports.clear();
                    step.ports(&mut ports);
                    ports.sort_unstable();
                    msgs.clear();
                    step.step(&UnitCost, Some(&mut msgs)).unwrap();
                    let mut sent: Vec<(usize, usize)> =
                        msgs.iter().map(|&(f, t, _)| (f, t)).collect();
                    sent.sort_unstable();
                    assert_eq!(ports, sent, "reduce ports predict sends p={p} root={root}");
                }
                let (res, scratch) = step.finish();
                pool.put(scratch);
                let (rstats, rbuf) = res.unwrap();
                stats_eq(&rstats, &bstats, &format!("stepped reduce p={p} root={root}"));
                assert_eq!(rbuf.unwrap(), bbuf, "stepped reduce payload p={p} root={root}");
            }
        }
        assert!(pool.idle() >= 1, "finished steps return scratch to the pool");
    }

    #[test]
    fn stepped_run_surfaces_blocking_errors() {
        // A corrupted schedule must fail a stepped run with the same
        // error value (and round) the blocking run reports.
        let sk = Arc::new(Skips::new(17));
        let mut table = ScheduleTable::build(&sk);
        let q = table.q();
        table.recv_row_mut(1)[0] = -(q as i64) as i8;
        let table = Arc::new(table);
        let geom = BlockGeometry::new(34, 2);
        let eng = CirculantEngine::new(table.clone(), 0, geom);
        let blocking = eng.run_bcast(4, &UnitCost).unwrap_err();
        let mut step =
            EngineStep::<u32>::bcast(CirculantEngine::new(table, 0, geom), EngineScratch::new(), 4);
        let stepped = loop {
            match step.step(&UnitCost, None) {
                Ok(()) => assert!(!step.is_done(), "corrupted run must not complete"),
                Err(e) => break e,
            }
        };
        assert_eq!(stepped, blocking);
    }

    #[test]
    fn scratch_pool_reuses_by_type() {
        let pool = ScratchPool::new();
        let mut a = pool.take::<i64>();
        a.holds.reserve(1024);
        let marker = a.holds.capacity();
        pool.put(a);
        // A different element type gets a fresh scratch...
        let b = pool.take::<u32>();
        assert_eq!(b.holds.capacity(), 0);
        assert_eq!(pool.idle(), 1);
        // ...while the matching type gets the pooled one back.
        let c = pool.take::<i64>();
        assert_eq!(c.holds.capacity(), marker);
        assert_eq!(pool.idle(), 0);
        pool.put(b);
        pool.put(c);
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn occupancy_matches_bruteforce() {
        for p in [2usize, 9, 17, 33] {
            let sk = Arc::new(Skips::new(p));
            let table = Arc::new(ScheduleTable::build(&sk));
            for n in [1usize, 3, 7, 11] {
                let eng = CirculantEngine::new(table.clone(), 0, BlockGeometry::new(n * 2, n));
                for rel in 0..p {
                    let row = table.recv_row(rel);
                    let (count, first) = eng.row_occupancy(row, |_| true);
                    let mut bcount = 0usize;
                    let mut bfirst = usize::MAX;
                    for j in 0..eng.rounds {
                        let (k, delta) = eng.round_params(j);
                        if row[k] as i64 + delta >= 0 {
                            bcount += 1;
                            bfirst = bfirst.min(j);
                        }
                    }
                    assert_eq!(count, bcount, "p={p} n={n} rel={rel}");
                    assert_eq!(first, bfirst, "p={p} n={n} rel={rel}");
                }
            }
        }
    }
}
