//! The round-based, fully connected, one-ported, bidirectional
//! message-passing machine (the paper's model, Section 1).
//!
//! Collectives are implemented as per-rank state machines
//! ([`RankProc`]); [`Network::run`] drives all `p` of them in lockstep
//! rounds, enforcing the machine model:
//!
//! * **fully connected** — any rank may send to any other rank;
//! * **one-ported** — per round each rank sends at most one message *and*
//!   receives at most one message (send and receive may happen
//!   simultaneously, possibly with different partners);
//! * **round-synchronous** — a message sent in round `i` is delivered in
//!   round `i`; nothing is buffered across rounds.
//!
//! Violations of one-portedness (two messages to the same rank in one
//! round, self-messages) are hard errors: they indicate a broken schedule
//! and abort the run — this is the simulator's most valuable service as a
//! correctness instrument.

use super::cost::{CostModel, LogPClock, LogPParams};

/// An outgoing message declared by a rank for the current round.
#[derive(Debug, Clone)]
pub struct Msg<T> {
    pub to: usize,
    pub data: Vec<T>,
}

/// A collective, viewed from one rank, as a round-stepped state machine.
pub trait RankProc<T> {
    /// The message this rank sends in `round`, or `None`.
    fn send(&mut self, round: usize) -> Option<Msg<T>>;

    /// The rank this rank expects to receive from in `round`, or `None`.
    ///
    /// In schedule-driven collectives both endpoints know each round's
    /// communication fully in advance (no metadata is exchanged — a key
    /// point of the paper); the simulator cross-checks expectation against
    /// actual delivery, and the threaded runtime uses it to post receives.
    fn expects(&self, round: usize) -> Option<usize>;

    /// Deliver the message this rank receives in `round` (called after all
    /// `send`s of the round are collected).
    fn recv(&mut self, round: usize, from: usize, data: Vec<T>);

    /// Number of rounds this rank participates in (the network runs until
    /// the max over ranks).
    fn rounds(&self) -> usize;
}

/// Aggregated statistics of one collective run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Rounds executed (max over ranks of [`RankProc::rounds`]).
    pub rounds: usize,
    /// Rounds in which at least one message flew.
    pub active_rounds: usize,
    /// Total messages.
    pub messages: usize,
    /// Total payload bytes moved (sum over messages).
    pub bytes: usize,
    /// Max payload bytes sent+received by any single rank (the one-port
    /// bottleneck volume).
    pub max_rank_bytes: usize,
    /// Simulated completion time under the run's cost model, seconds:
    /// `sum over rounds of max over the round's messages of msg_time`.
    pub time: f64,
    /// Predicted completion time under the LogP cost plane
    /// ([`super::cost::LogPClock`] over the executed trace), seconds —
    /// `Some` only when LogP parameters were configured for the run.
    pub logp_time: Option<f64>,
}

/// Simulation errors — all indicate a broken schedule/collective.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    /// Two senders targeted the same receiver in one round.
    ReceivePortBusy { round: usize, to: usize, first_from: usize, second_from: usize },
    /// A rank addressed itself.
    SelfMessage { round: usize, rank: usize },
    /// A rank addressed a non-existent rank.
    BadTarget { round: usize, rank: usize, to: usize },
    /// A message arrived at a rank that did not expect one (or expected a
    /// different sender) — the send/receive schedules disagree.
    UnexpectedMessage { round: usize, to: usize, from: usize, expected: Option<usize> },
    /// A rank expected a message that never arrived.
    MissingMessage { round: usize, rank: usize, expected_from: usize },
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::ReceivePortBusy { round, to, first_from, second_from } => write!(
                f,
                "round {round}: receive port of rank {to} busy (from {first_from} and {second_from})"
            ),
            SimError::SelfMessage { round, rank } => {
                write!(f, "round {round}: rank {rank} sent to itself")
            }
            SimError::BadTarget { round, rank, to } => {
                write!(f, "round {round}: rank {rank} sent to non-existent rank {to}")
            }
            SimError::UnexpectedMessage { round, to, from, expected } => write!(
                f,
                "round {round}: rank {to} got message from {from} but expected {expected:?}"
            ),
            SimError::MissingMessage { round, rank, expected_from } => write!(
                f,
                "round {round}: rank {rank} expected a message from {expected_from}, none came"
            ),
        }
    }
}

impl std::error::Error for SimError {}

/// The simulated machine: `p` ranks, element type byte-size `elem_bytes`
/// (used for cost accounting).
pub struct Network {
    p: usize,
}

impl Network {
    pub fn new(p: usize) -> Self {
        assert!(p > 0);
        Network { p }
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    /// Run one collective to completion: `procs[r]` is rank `r`'s state
    /// machine. Returns run statistics; errors on machine-model violations.
    pub fn run<T: Clone, P: RankProc<T>>(
        &mut self,
        procs: &mut [P],
        elem_bytes: usize,
        cost: &dyn CostModel,
    ) -> Result<RunStats, SimError> {
        self.run_logp(procs, elem_bytes, cost, None)
    }

    /// [`Network::run`] with the cost plane attached: when `logp` is
    /// given, the executed trace is additionally clocked by a
    /// [`LogPClock`] and the prediction lands in `RunStats::logp_time`.
    pub fn run_logp<T: Clone, P: RankProc<T>>(
        &mut self,
        procs: &mut [P],
        elem_bytes: usize,
        cost: &dyn CostModel,
        logp: Option<&LogPParams>,
    ) -> Result<RunStats, SimError> {
        assert_eq!(procs.len(), self.p);
        let total_rounds = procs.iter().map(|pr| pr.rounds()).max().unwrap_or(0);
        let mut stats = RunStats { rounds: total_rounds, ..Default::default() };
        let mut rank_bytes = vec![0usize; self.p];
        let mut clock = logp.map(|p| LogPClock::new(*p));

        // Reusable per-round delivery slots: receiver -> (sender, data).
        let mut inbox: Vec<Option<(usize, Vec<T>)>> = (0..self.p).map(|_| None).collect();

        for round in 0..total_rounds {
            lockstep_round(
                procs,
                round,
                &mut inbox,
                &mut stats,
                &mut rank_bytes,
                elem_bytes,
                cost,
                None,
                clock.as_mut(),
            )?;
        }
        stats.max_rank_bytes = rank_bytes.into_iter().max().unwrap_or(0);
        stats.logp_time = clock.map(|c| c.total());
        Ok(stats)
    }
}

/// One lockstep round over `procs` — the single machine-model round body
/// shared by [`Network::run`] and [`StepNet::step`], so blocking and
/// stepped execution enforce the identical model by construction: send
/// collection (self/target/port checks in rank order, accounting),
/// expectation cross-check and delivery in rank order. `msgs` (when
/// given) receives the round's executed `(from, to, bytes)` triples;
/// `clock` (when given) is fed the same triples and closed with
/// [`LogPClock::end_round`].
#[allow(clippy::too_many_arguments)]
fn lockstep_round<T: Clone, P: RankProc<T>>(
    procs: &mut [P],
    round: usize,
    inbox: &mut [Option<(usize, Vec<T>)>],
    stats: &mut RunStats,
    rank_bytes: &mut [usize],
    elem_bytes: usize,
    cost: &dyn CostModel,
    mut msgs: Option<&mut Vec<(usize, usize, usize)>>,
    mut clock: Option<&mut LogPClock>,
) -> Result<(), SimError> {
    let p = procs.len();
    let mut round_time = 0.0f64;
    let mut any = false;

    // Collect sends.
    for r in 0..p {
        if let Some(msg) = procs[r].send(round) {
            if msg.to == r {
                return Err(SimError::SelfMessage { round, rank: r });
            }
            if msg.to >= p {
                return Err(SimError::BadTarget { round, rank: r, to: msg.to });
            }
            if let Some((first, _)) = &inbox[msg.to] {
                return Err(SimError::ReceivePortBusy {
                    round,
                    to: msg.to,
                    first_from: *first,
                    second_from: r,
                });
            }
            let bytes = msg.data.len() * elem_bytes;
            stats.messages += 1;
            stats.bytes += bytes;
            rank_bytes[r] += bytes;
            rank_bytes[msg.to] += bytes;
            round_time = round_time.max(cost.msg_time(r, msg.to, bytes));
            any = true;
            if let Some(out) = msgs.as_mut() {
                out.push((r, msg.to, bytes));
            }
            if let Some(c) = clock.as_mut() {
                c.msg(r, msg.to, bytes);
            }
            inbox[msg.to] = Some((r, msg.data));
        }
    }

    // Cross-check expectations, then deliver.
    for (to, slot) in inbox.iter_mut().enumerate() {
        let expected = procs[to].expects(round);
        match (slot.take(), expected) {
            (Some((from, data)), Some(exp)) if exp == from => {
                procs[to].recv(round, from, data);
            }
            (Some((from, _)), exp) => {
                return Err(SimError::UnexpectedMessage { round, to, from, expected: exp });
            }
            (None, Some(exp)) => {
                return Err(SimError::MissingMessage { round, rank: to, expected_from: exp });
            }
            (None, None) => {}
        }
    }

    if any {
        stats.active_rounds += 1;
        stats.time += round_time;
    }
    if let Some(c) = clock {
        c.end_round();
    }
    Ok(())
}

/// A resumable, round-steppable driver over one collective's rank state
/// machines — the per-round counterpart of [`Network::run`], with the
/// identical machine-model enforcement, check order and accounting, so a
/// collective stepped round by round produces bit-identical results to a
/// blocking run. This is what lets the traffic plane
/// ([`crate::comm::traffic::TrafficEngine`]) interleave the rounds of
/// many concurrent collectives under one cross-operation port ledger.
///
/// Two extra affordances over `Network::run`:
///
/// * [`StepNet::expected_ports`] reports the `(from, to)` pairs the next
///   round will use *without* driving the state machines (derived from
///   the receivers' [`RankProc::expects`] — in schedule-driven
///   collectives both endpoints know each round in advance, so
///   expectations predict the sends exactly; the lockstep cross-check in
///   [`StepNet::step`] still verifies this on every executed round).
/// * [`StepNet::step`] optionally reports the round's executed
///   `(from, to, bytes)` messages, feeding the traffic plane's port
///   trace and aggregate cost accounting.
pub struct StepNet<T, P> {
    procs: Vec<P>,
    rounds: usize,
    next: usize,
    stats: RunStats,
    rank_bytes: Vec<usize>,
    inbox: Vec<Option<(usize, Vec<T>)>>,
    logp: Option<LogPClock>,
}

impl<T: Clone, P: RankProc<T>> StepNet<T, P> {
    pub fn new(procs: Vec<P>) -> Self {
        let p = procs.len();
        assert!(p > 0);
        let rounds = procs.iter().map(|pr| pr.rounds()).max().unwrap_or(0);
        StepNet {
            procs,
            rounds,
            next: 0,
            stats: RunStats { rounds, ..Default::default() },
            rank_bytes: vec![0usize; p],
            inbox: (0..p).map(|_| None).collect(),
            logp: None,
        }
    }

    /// Attach the LogP cost plane: every subsequently stepped round is
    /// also clocked by a [`LogPClock`] and [`StepNet::finish`] reports
    /// the prediction in `RunStats::logp_time`. Call before the first
    /// [`StepNet::step`] so the whole trace is covered.
    pub fn set_logp(&mut self, params: &LogPParams) {
        self.logp = Some(LogPClock::new(*params));
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.procs.len()
    }

    /// Total rounds (max over ranks of [`RankProc::rounds`]).
    #[inline]
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// The round the next [`StepNet::step`] will execute.
    #[inline]
    pub fn next_round(&self) -> usize {
        self.next
    }

    #[inline]
    pub fn is_done(&self) -> bool {
        self.next >= self.rounds
    }

    /// The `(from, to)` pairs the next round is expected to use, from the
    /// receivers' schedules. No-op when the run is complete.
    pub fn expected_ports(&self, out: &mut Vec<(usize, usize)>) {
        if self.is_done() {
            return;
        }
        for (to, pr) in self.procs.iter().enumerate() {
            if let Some(from) = pr.expects(self.next) {
                out.push((from, to));
            }
        }
    }

    /// Execute the next round — the shared [`lockstep_round`] body, so a
    /// stepped run enforces exactly what [`Network::run`] enforces. On
    /// success, `msgs` (when given) receives the round's
    /// `(from, to, bytes)` triples; on error the run is poisoned exactly
    /// where a blocking run would have aborted.
    pub fn step(
        &mut self,
        elem_bytes: usize,
        cost: &dyn CostModel,
        msgs: Option<&mut Vec<(usize, usize, usize)>>,
    ) -> Result<(), SimError> {
        assert!(!self.is_done(), "step called on a completed run");
        let round = self.next;
        lockstep_round(
            &mut self.procs,
            round,
            &mut self.inbox,
            &mut self.stats,
            &mut self.rank_bytes,
            elem_bytes,
            cost,
            msgs,
            self.logp.as_mut(),
        )?;
        self.next = round + 1;
        Ok(())
    }

    /// Final statistics and state machines; call once every round has
    /// been stepped.
    pub fn finish(mut self) -> (RunStats, Vec<P>) {
        assert!(self.is_done(), "finish called with rounds remaining");
        self.stats.max_rank_bytes = self.rank_bytes.iter().copied().max().unwrap_or(0);
        self.stats.logp_time = self.logp.map(|c| c.total());
        (self.stats, self.procs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cost::UnitCost;

    /// Trivial ring shift: rank r sends its value to r+1 each round.
    struct RingShift {
        rank: usize,
        p: usize,
        rounds: usize,
        val: Vec<u32>,
        seen: Vec<usize>,
    }

    impl RankProc<u32> for RingShift {
        fn send(&mut self, _round: usize) -> Option<Msg<u32>> {
            Some(Msg { to: (self.rank + 1) % self.p, data: self.val.clone() })
        }
        fn expects(&self, _round: usize) -> Option<usize> {
            Some((self.rank + self.p - 1) % self.p)
        }
        fn recv(&mut self, _round: usize, from: usize, data: Vec<u32>) {
            self.seen.push(from);
            self.val = data;
        }
        fn rounds(&self) -> usize {
            self.rounds
        }
    }

    #[test]
    fn ring_shift_runs_and_counts() {
        let p = 5;
        let mut procs: Vec<RingShift> = (0..p)
            .map(|r| RingShift { rank: r, p, rounds: p - 1, val: vec![r as u32], seen: vec![] })
            .collect();
        let mut net = Network::new(p);
        let stats = net.run(&mut procs, 4, &UnitCost).unwrap();
        assert_eq!(stats.rounds, p - 1);
        assert_eq!(stats.messages, p * (p - 1));
        assert_eq!(stats.time, (p - 1) as f64);
        // After p-1 shifts every rank holds its predecessor's... the value
        // that started p-1 positions back = rank + 1 mod p.
        for (r, pr) in procs.iter().enumerate() {
            assert_eq!(pr.val, vec![((r + 1) % p) as u32]);
        }
    }

    /// Two ranks target the same receiver -> one-port violation.
    struct Collider {
        rank: usize,
    }

    impl RankProc<u8> for Collider {
        fn send(&mut self, _round: usize) -> Option<Msg<u8>> {
            if self.rank == 0 || self.rank == 1 {
                Some(Msg { to: 2, data: vec![1] })
            } else {
                None
            }
        }
        fn expects(&self, _round: usize) -> Option<usize> {
            None
        }
        fn recv(&mut self, _round: usize, _from: usize, _data: Vec<u8>) {}
        fn rounds(&self) -> usize {
            1
        }
    }

    #[test]
    fn one_port_violation_detected() {
        let mut procs: Vec<Collider> = (0..3).map(|r| Collider { rank: r }).collect();
        let mut net = Network::new(3);
        let err = net.run(&mut procs, 1, &UnitCost).unwrap_err();
        matches!(err, SimError::ReceivePortBusy { .. })
            .then_some(())
            .expect("expected ReceivePortBusy");
    }

    /// Self-message detection.
    struct Selfie;
    impl RankProc<u8> for Selfie {
        fn send(&mut self, _round: usize) -> Option<Msg<u8>> {
            Some(Msg { to: 0, data: vec![] })
        }
        fn expects(&self, _round: usize) -> Option<usize> {
            None
        }
        fn recv(&mut self, _r: usize, _f: usize, _d: Vec<u8>) {}
        fn rounds(&self) -> usize {
            1
        }
    }

    #[test]
    fn self_message_detected() {
        let mut procs = vec![Selfie];
        let mut net = Network::new(1);
        assert_eq!(
            net.run(&mut procs, 1, &UnitCost).unwrap_err(),
            SimError::SelfMessage { round: 0, rank: 0 }
        );
    }

    #[test]
    fn stepnet_matches_blocking_run() {
        let p = 5;
        let mk = || -> Vec<RingShift> {
            (0..p)
                .map(|r| RingShift { rank: r, p, rounds: p - 1, val: vec![r as u32], seen: vec![] })
                .collect()
        };
        let mut blocking = mk();
        let bstats = Network::new(p).run(&mut blocking, 4, &UnitCost).unwrap();

        let mut step = StepNet::new(mk());
        let mut ports = Vec::new();
        let mut msgs = Vec::new();
        while !step.is_done() {
            ports.clear();
            step.expected_ports(&mut ports);
            assert_eq!(ports.len(), p, "every rank receives every round");
            msgs.clear();
            step.step(4, &UnitCost, Some(&mut msgs)).unwrap();
            assert_eq!(msgs.len(), p);
            // Expectations predicted the executed sends exactly.
            let mut want: Vec<(usize, usize)> = msgs.iter().map(|&(f, t, _)| (f, t)).collect();
            want.sort_unstable();
            ports.sort_unstable();
            assert_eq!(ports, want);
        }
        let (sstats, sprocs) = step.finish();
        assert_eq!(sstats.rounds, bstats.rounds);
        assert_eq!(sstats.active_rounds, bstats.active_rounds);
        assert_eq!(sstats.messages, bstats.messages);
        assert_eq!(sstats.bytes, bstats.bytes);
        assert_eq!(sstats.max_rank_bytes, bstats.max_rank_bytes);
        assert!((sstats.time - bstats.time).abs() < 1e-12);
        for (a, b) in blocking.iter().zip(&sprocs) {
            assert_eq!(a.val, b.val);
            assert_eq!(a.seen, b.seen);
        }
    }

    #[test]
    fn stepnet_reports_violations_like_blocking() {
        let mut blocking: Vec<Collider> = (0..3).map(|r| Collider { rank: r }).collect();
        let berr = Network::new(3).run(&mut blocking, 1, &UnitCost).unwrap_err();
        let mut step = StepNet::new((0..3).map(|r| Collider { rank: r }).collect::<Vec<_>>());
        let serr = step.step(1, &UnitCost, None).unwrap_err();
        assert_eq!(berr, serr);
    }
}
