//! The round-based, fully connected, one-ported, bidirectional
//! message-passing machine (the paper's model, Section 1).
//!
//! Collectives are implemented as per-rank state machines
//! ([`RankProc`]); [`Network::run`] drives all `p` of them in lockstep
//! rounds, enforcing the machine model:
//!
//! * **fully connected** — any rank may send to any other rank;
//! * **one-ported** — per round each rank sends at most one message *and*
//!   receives at most one message (send and receive may happen
//!   simultaneously, possibly with different partners);
//! * **round-synchronous** — a message sent in round `i` is delivered in
//!   round `i`; nothing is buffered across rounds.
//!
//! Violations of one-portedness (two messages to the same rank in one
//! round, self-messages) are hard errors: they indicate a broken schedule
//! and abort the run — this is the simulator's most valuable service as a
//! correctness instrument.

use super::cost::CostModel;

/// An outgoing message declared by a rank for the current round.
#[derive(Debug, Clone)]
pub struct Msg<T> {
    pub to: usize,
    pub data: Vec<T>,
}

/// A collective, viewed from one rank, as a round-stepped state machine.
pub trait RankProc<T> {
    /// The message this rank sends in `round`, or `None`.
    fn send(&mut self, round: usize) -> Option<Msg<T>>;

    /// The rank this rank expects to receive from in `round`, or `None`.
    ///
    /// In schedule-driven collectives both endpoints know each round's
    /// communication fully in advance (no metadata is exchanged — a key
    /// point of the paper); the simulator cross-checks expectation against
    /// actual delivery, and the threaded runtime uses it to post receives.
    fn expects(&self, round: usize) -> Option<usize>;

    /// Deliver the message this rank receives in `round` (called after all
    /// `send`s of the round are collected).
    fn recv(&mut self, round: usize, from: usize, data: Vec<T>);

    /// Number of rounds this rank participates in (the network runs until
    /// the max over ranks).
    fn rounds(&self) -> usize;
}

/// Aggregated statistics of one collective run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Rounds executed (max over ranks of [`RankProc::rounds`]).
    pub rounds: usize,
    /// Rounds in which at least one message flew.
    pub active_rounds: usize,
    /// Total messages.
    pub messages: usize,
    /// Total payload bytes moved (sum over messages).
    pub bytes: usize,
    /// Max payload bytes sent+received by any single rank (the one-port
    /// bottleneck volume).
    pub max_rank_bytes: usize,
    /// Simulated completion time under the run's cost model, seconds:
    /// `sum over rounds of max over the round's messages of msg_time`.
    pub time: f64,
}

/// Simulation errors — all indicate a broken schedule/collective.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    /// Two senders targeted the same receiver in one round.
    ReceivePortBusy { round: usize, to: usize, first_from: usize, second_from: usize },
    /// A rank addressed itself.
    SelfMessage { round: usize, rank: usize },
    /// A rank addressed a non-existent rank.
    BadTarget { round: usize, rank: usize, to: usize },
    /// A message arrived at a rank that did not expect one (or expected a
    /// different sender) — the send/receive schedules disagree.
    UnexpectedMessage { round: usize, to: usize, from: usize, expected: Option<usize> },
    /// A rank expected a message that never arrived.
    MissingMessage { round: usize, rank: usize, expected_from: usize },
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::ReceivePortBusy { round, to, first_from, second_from } => write!(
                f,
                "round {round}: receive port of rank {to} busy (from {first_from} and {second_from})"
            ),
            SimError::SelfMessage { round, rank } => {
                write!(f, "round {round}: rank {rank} sent to itself")
            }
            SimError::BadTarget { round, rank, to } => {
                write!(f, "round {round}: rank {rank} sent to non-existent rank {to}")
            }
            SimError::UnexpectedMessage { round, to, from, expected } => write!(
                f,
                "round {round}: rank {to} got message from {from} but expected {expected:?}"
            ),
            SimError::MissingMessage { round, rank, expected_from } => write!(
                f,
                "round {round}: rank {rank} expected a message from {expected_from}, none came"
            ),
        }
    }
}

impl std::error::Error for SimError {}

/// The simulated machine: `p` ranks, element type byte-size `elem_bytes`
/// (used for cost accounting).
pub struct Network {
    p: usize,
}

impl Network {
    pub fn new(p: usize) -> Self {
        assert!(p > 0);
        Network { p }
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    /// Run one collective to completion: `procs[r]` is rank `r`'s state
    /// machine. Returns run statistics; errors on machine-model violations.
    pub fn run<T: Clone, P: RankProc<T>>(
        &mut self,
        procs: &mut [P],
        elem_bytes: usize,
        cost: &dyn CostModel,
    ) -> Result<RunStats, SimError> {
        assert_eq!(procs.len(), self.p);
        let total_rounds = procs.iter().map(|pr| pr.rounds()).max().unwrap_or(0);
        let mut stats = RunStats { rounds: total_rounds, ..Default::default() };
        let mut rank_bytes = vec![0usize; self.p];

        // Reusable per-round delivery slots: receiver -> (sender, data).
        let mut inbox: Vec<Option<(usize, Vec<T>)>> = (0..self.p).map(|_| None).collect();

        for round in 0..total_rounds {
            let mut round_time = 0.0f64;
            let mut any = false;

            // Collect sends.
            for r in 0..self.p {
                if let Some(msg) = procs[r].send(round) {
                    if msg.to == r {
                        return Err(SimError::SelfMessage { round, rank: r });
                    }
                    if msg.to >= self.p {
                        return Err(SimError::BadTarget { round, rank: r, to: msg.to });
                    }
                    if let Some((first, _)) = &inbox[msg.to] {
                        return Err(SimError::ReceivePortBusy {
                            round,
                            to: msg.to,
                            first_from: *first,
                            second_from: r,
                        });
                    }
                    let bytes = msg.data.len() * elem_bytes;
                    stats.messages += 1;
                    stats.bytes += bytes;
                    rank_bytes[r] += bytes;
                    rank_bytes[msg.to] += bytes;
                    round_time = round_time.max(cost.msg_time(r, msg.to, bytes));
                    any = true;
                    inbox[msg.to] = Some((r, msg.data));
                }
            }

            // Cross-check expectations, then deliver.
            for (to, slot) in inbox.iter_mut().enumerate() {
                let expected = procs[to].expects(round);
                match (slot.take(), expected) {
                    (Some((from, data)), Some(exp)) if exp == from => {
                        procs[to].recv(round, from, data);
                    }
                    (Some((from, _)), exp) => {
                        return Err(SimError::UnexpectedMessage { round, to, from, expected: exp });
                    }
                    (None, Some(exp)) => {
                        return Err(SimError::MissingMessage {
                            round,
                            rank: to,
                            expected_from: exp,
                        });
                    }
                    (None, None) => {}
                }
            }

            if any {
                stats.active_rounds += 1;
                stats.time += round_time;
            }
        }
        stats.max_rank_bytes = rank_bytes.into_iter().max().unwrap_or(0);
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cost::UnitCost;

    /// Trivial ring shift: rank r sends its value to r+1 each round.
    struct RingShift {
        rank: usize,
        p: usize,
        rounds: usize,
        val: Vec<u32>,
        seen: Vec<usize>,
    }

    impl RankProc<u32> for RingShift {
        fn send(&mut self, _round: usize) -> Option<Msg<u32>> {
            Some(Msg { to: (self.rank + 1) % self.p, data: self.val.clone() })
        }
        fn expects(&self, _round: usize) -> Option<usize> {
            Some((self.rank + self.p - 1) % self.p)
        }
        fn recv(&mut self, _round: usize, from: usize, data: Vec<u32>) {
            self.seen.push(from);
            self.val = data;
        }
        fn rounds(&self) -> usize {
            self.rounds
        }
    }

    #[test]
    fn ring_shift_runs_and_counts() {
        let p = 5;
        let mut procs: Vec<RingShift> = (0..p)
            .map(|r| RingShift { rank: r, p, rounds: p - 1, val: vec![r as u32], seen: vec![] })
            .collect();
        let mut net = Network::new(p);
        let stats = net.run(&mut procs, 4, &UnitCost).unwrap();
        assert_eq!(stats.rounds, p - 1);
        assert_eq!(stats.messages, p * (p - 1));
        assert_eq!(stats.time, (p - 1) as f64);
        // After p-1 shifts every rank holds its predecessor's... the value
        // that started p-1 positions back = rank + 1 mod p.
        for (r, pr) in procs.iter().enumerate() {
            assert_eq!(pr.val, vec![((r + 1) % p) as u32]);
        }
    }

    /// Two ranks target the same receiver -> one-port violation.
    struct Collider {
        rank: usize,
    }

    impl RankProc<u8> for Collider {
        fn send(&mut self, _round: usize) -> Option<Msg<u8>> {
            if self.rank == 0 || self.rank == 1 {
                Some(Msg { to: 2, data: vec![1] })
            } else {
                None
            }
        }
        fn expects(&self, _round: usize) -> Option<usize> {
            None
        }
        fn recv(&mut self, _round: usize, _from: usize, _data: Vec<u8>) {}
        fn rounds(&self) -> usize {
            1
        }
    }

    #[test]
    fn one_port_violation_detected() {
        let mut procs: Vec<Collider> = (0..3).map(|r| Collider { rank: r }).collect();
        let mut net = Network::new(3);
        let err = net.run(&mut procs, 1, &UnitCost).unwrap_err();
        matches!(err, SimError::ReceivePortBusy { .. })
            .then_some(())
            .expect("expected ReceivePortBusy");
    }

    /// Self-message detection.
    struct Selfie;
    impl RankProc<u8> for Selfie {
        fn send(&mut self, _round: usize) -> Option<Msg<u8>> {
            Some(Msg { to: 0, data: vec![] })
        }
        fn expects(&self, _round: usize) -> Option<usize> {
            None
        }
        fn recv(&mut self, _r: usize, _f: usize, _d: Vec<u8>) {}
        fn rounds(&self) -> usize {
            1
        }
    }

    #[test]
    fn self_message_detected() {
        let mut procs = vec![Selfie];
        let mut net = Network::new(1);
        assert_eq!(
            net.run(&mut procs, 1, &UnitCost).unwrap_err(),
            SimError::SelfMessage { round: 0, rank: 0 }
        );
    }
}
