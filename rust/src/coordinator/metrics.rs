//! Lightweight metrics for the coordinator: per-operation counters and
//! simple aggregates, rendered as text (the moral equivalent of an MPI
//! library's PMPI counters).

use std::collections::BTreeMap;
use std::sync::Mutex;

#[derive(Debug, Clone, Default)]
struct OpStats {
    count: u64,
    failures: u64,
    sim_time_total: f64,
    wall_total: f64,
    wall_max: f64,
}

/// Thread-safe metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    ops: Mutex<BTreeMap<String, OpStats>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one collective execution.
    pub fn observe(&self, op: &str, sim_time: f64, wall: f64, valid: bool) {
        let mut g = self.ops.lock().unwrap();
        let s = g.entry(op.to_string()).or_default();
        s.count += 1;
        if !valid {
            s.failures += 1;
        }
        s.sim_time_total += sim_time;
        s.wall_total += wall;
        s.wall_max = s.wall_max.max(wall);
    }

    /// Total operations observed.
    pub fn total(&self) -> u64 {
        self.ops.lock().unwrap().values().map(|s| s.count).sum()
    }

    /// Render a text report.
    pub fn render(&self) -> String {
        let g = self.ops.lock().unwrap();
        let mut out = String::from("op                count  failures  sim_time_total  wall_avg  wall_max\n");
        for (name, s) in g.iter() {
            out.push_str(&format!(
                "{name:<16} count={:<5} fail={:<4} sim={:<12.6} wavg={:<9.6} wmax={:.6}\n",
                s.count,
                s.failures,
                s.sim_time_total,
                if s.count > 0 { s.wall_total / s.count as f64 } else { 0.0 },
                s.wall_max,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_and_render() {
        let m = Metrics::new();
        m.observe("Bcast", 0.5, 0.01, true);
        m.observe("Bcast", 0.7, 0.02, false);
        m.observe("Reduce", 0.1, 0.005, true);
        assert_eq!(m.total(), 3);
        let text = m.render();
        assert!(text.contains("Bcast"));
        assert!(text.contains("fail=1"));
        assert!(text.contains("Reduce"));
    }

    #[test]
    fn threaded_observe() {
        let m = std::sync::Arc::new(Metrics::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.observe("X", 0.0, 0.0, true);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.total(), 400);
    }
}
