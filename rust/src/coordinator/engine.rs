//! The coordinator engine: a thin service layer over
//! [`crate::comm::Communicator`]. It plans a request, hands it to a
//! communicator that shares the engine-wide [`ScheduleCache`], validates
//! the payloads, and records metrics — the role of an MPI library's
//! collective framework behind the `cbcast` CLI and the benchmark
//! drivers. All algorithm execution lives in `comm`; the engine
//! synthesises test data, checks results and observes.

use std::sync::Arc;
use std::time::Instant;

use crate::collectives::{ReduceOp, SumOp};
use crate::comm::{
    AllgathervReq, AllreduceReq, BcastReq, CommBuilder, CommError, Communicator, Kind,
    ReduceReq, ReduceScatterReq,
};
use crate::schedule::ScheduleCache;
use crate::sim::cost::CostModel;
use crate::sim::network::RunStats;

use super::metrics::Metrics;
use super::planner::{plan, Plan, Request, TuningParams};

#[cfg(test)]
use super::planner::{Algo, Dist};

/// What the engine reports per request.
#[derive(Debug, Clone)]
pub struct Report {
    pub plan: Plan,
    pub stats: RunStats,
    /// Wall-clock of the whole simulated run (schedule computation +
    /// simulation + validation), seconds.
    pub wall: f64,
    /// Simulated completion time under the chosen cost model, seconds.
    pub sim_time: f64,
    /// Payload checksum validation outcome.
    pub valid: bool,
}

/// The engine. Owns the schedule cache and metrics; cost model and ⊕ are
/// per-call so benches can sweep them.
pub struct Engine {
    pub cache: Arc<ScheduleCache>,
    pub metrics: Metrics,
    pub tuning: TuningParams,
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine {
    pub fn new() -> Self {
        Engine {
            cache: Arc::new(ScheduleCache::new()),
            metrics: Metrics::new(),
            tuning: TuningParams::default(),
        }
    }

    /// A communicator for `p` ranks sharing this engine's schedule cache
    /// and tuning constants — what every request runs through (and what
    /// callers wanting the typed API directly should use).
    pub fn communicator(&self, p: usize) -> Communicator {
        CommBuilder::new(p).cache(self.cache.clone()).tuning(self.tuning.clone()).build()
    }

    /// Execute one request with element type i64 and SumOp (the generic
    /// driver used by the CLI; benches use the typed entry points below).
    pub fn run(&self, req: &Request, cost: &dyn CostModel) -> Result<Report, CommError> {
        self.run_with_op(req, cost, Arc::new(SumOp))
    }

    /// Execute one request with a caller-chosen reduction operator.
    pub fn run_with_op(
        &self,
        req: &Request,
        cost: &dyn CostModel,
        op: Arc<dyn ReduceOp<i64>>,
    ) -> Result<Report, CommError> {
        let t0 = Instant::now();
        let pl = plan(req, &self.tuning);
        let comm = self.communicator(req.p);
        let p = req.p;
        let (stats, valid) = match req.kind {
            Kind::Bcast => {
                let data = test_pattern(req.m, 1);
                let creq = BcastReq::new(req.root, &data)
                    .blocks(pl.n)
                    .algo(pl.algo)
                    .elem_bytes(req.elem_bytes);
                let out = comm.bcast_with(creq, cost)?;
                let ok = out.all_received() && out.buffers.iter().all(|b| b == &data);
                (out.stats, ok)
            }
            Kind::Reduce => {
                let inputs: Vec<Vec<i64>> =
                    (0..p).map(|r| test_pattern(req.m, r as i64)).collect();
                let expect = column_sums(&inputs);
                let creq = ReduceReq::new(req.root, &inputs, op)
                    .blocks(pl.n)
                    .algo(pl.algo)
                    .elem_bytes(req.elem_bytes);
                let out = comm.reduce_with(creq, cost)?;
                let ok = out.buffers == expect;
                (out.stats, ok)
            }
            Kind::Allgatherv => {
                let counts = req.dist.counts(p, req.m);
                let inputs = dist_inputs(&counts);
                let creq = AllgathervReq::new(&inputs)
                    .blocks(pl.n)
                    .algo(pl.algo)
                    .elem_bytes(req.elem_bytes);
                let out = comm.allgatherv_with(creq, cost)?;
                let ok = out
                    .buffers
                    .iter()
                    .all(|rows| rows.iter().zip(&inputs).all(|(row, inp)| row == inp));
                (out.stats, ok)
            }
            Kind::ReduceScatter => {
                let counts = req.dist.counts(p, req.m);
                let total: usize = counts.iter().sum();
                let inputs: Vec<Vec<i64>> =
                    (0..p).map(|r| test_pattern(total, r as i64)).collect();
                let sums = column_sums(&inputs);
                let creq = ReduceScatterReq::new(&inputs, &counts, op)
                    .blocks(pl.n)
                    .algo(pl.algo)
                    .elem_bytes(req.elem_bytes);
                let out = comm.reduce_scatter_with(creq, cost)?;
                let ok = check_chunks(&out.buffers, &sums, &counts);
                (out.stats, ok)
            }
            Kind::Allreduce => {
                let inputs: Vec<Vec<i64>> =
                    (0..p).map(|r| test_pattern(req.m, r as i64)).collect();
                let expect = column_sums(&inputs);
                let creq = AllreduceReq::new(&inputs, op)
                    .blocks(pl.n)
                    .algo(pl.algo)
                    .elem_bytes(req.elem_bytes);
                let out = comm.allreduce_with(creq, cost)?;
                let ok = out.buffers.iter().all(|b| b == &expect);
                (out.stats, ok)
            }
        };
        let wall = t0.elapsed().as_secs_f64();
        self.metrics.observe(&format!("{:?}", req.kind), stats.time, wall, valid);
        Ok(Report { plan: pl, sim_time: stats.time, stats, wall, valid })
    }
}

fn test_pattern(m: usize, seed: i64) -> Vec<i64> {
    (0..m as i64).map(|i| (seed * 31 + i * 7) % 1009).collect()
}

fn column_sums(inputs: &[Vec<i64>]) -> Vec<i64> {
    let m = inputs[0].len();
    (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect()
}

fn dist_inputs(counts: &[usize]) -> Vec<Vec<i64>> {
    counts
        .iter()
        .enumerate()
        .map(|(r, &c)| (0..c as i64).map(|i| (r as i64 * 131 + i) % 997).collect())
        .collect()
}

fn check_chunks(chunks: &[Vec<i64>], sums: &[i64], counts: &[usize]) -> bool {
    let mut off = 0usize;
    for (r, chunk) in chunks.iter().enumerate() {
        if chunk != &sums[off..off + counts[r]] {
            return false;
        }
        off += counts[r];
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cost::UnitCost;

    #[test]
    fn engine_runs_all_kinds_circulant() {
        let eng = Engine::new();
        for kind in
            [Kind::Bcast, Kind::Reduce, Kind::Allgatherv, Kind::ReduceScatter, Kind::Allreduce]
        {
            let mut req = Request::new(kind, 17, 1000);
            req.blocks = Some(4);
            let rep = eng.run(&req, &UnitCost).unwrap();
            assert!(rep.valid, "{kind:?} failed validation");
            assert!(rep.stats.messages > 0);
        }
    }

    #[test]
    fn engine_runs_baselines() {
        let eng = Engine::new();
        let combos = [
            (Kind::Bcast, Algo::Binomial),
            (Kind::Bcast, Algo::VanDeGeijn),
            (Kind::Bcast, Algo::OptTree),
            (Kind::Reduce, Algo::Binomial),
            (Kind::Reduce, Algo::OptTree),
            (Kind::Allgatherv, Algo::Ring),
            (Kind::ReduceScatter, Algo::Ring),
            (Kind::Allreduce, Algo::Ring),
        ];
        for (kind, algo) in combos {
            let mut req = Request::new(kind, 12, 600);
            req.algo = algo;
            let rep = eng.run(&req, &UnitCost).unwrap();
            assert!(rep.valid, "{kind:?}/{algo:?} failed validation");
        }
    }

    #[test]
    fn engine_distributions() {
        let eng = Engine::new();
        for dist in [Dist::Regular, Dist::Irregular, Dist::Degenerate] {
            let mut req = Request::new(Kind::Allgatherv, 9, 900);
            req.dist = dist;
            req.blocks = Some(3);
            let rep = eng.run(&req, &UnitCost).unwrap();
            assert!(rep.valid, "{dist:?}");
        }
    }

    #[test]
    fn engine_rejects_unsupported() {
        let eng = Engine::new();
        let mut req = Request::new(Kind::Allgatherv, 9, 900);
        req.algo = Algo::Binomial;
        assert!(matches!(
            eng.run(&req, &UnitCost),
            Err(CommError::Unsupported { .. })
        ));
    }

    #[test]
    fn engine_shares_schedule_cache_across_requests() {
        // The engine's communicators all share one cache: a second
        // request at the same p — even at a different root — must add no
        // new misses.
        let eng = Engine::new();
        let mut req = Request::new(Kind::Bcast, 17, 340);
        req.blocks = Some(4);
        eng.run(&req, &UnitCost).unwrap();
        let (_, misses_after_first) = eng.cache.stats();
        assert!(misses_after_first >= 17);
        req.root = 11;
        eng.run(&req, &UnitCost).unwrap();
        let (hits, misses) = eng.cache.stats();
        assert_eq!(misses, misses_after_first, "no recomputation on repeat traffic");
        assert!(hits >= 17);
    }

    #[test]
    fn metrics_accumulate() {
        let eng = Engine::new();
        let mut req = Request::new(Kind::Bcast, 9, 100);
        req.blocks = Some(2);
        for _ in 0..3 {
            eng.run(&req, &UnitCost).unwrap();
        }
        let text = eng.metrics.render();
        assert!(text.contains("Bcast"), "{text}");
        assert!(text.contains("count=3"), "{text}");
    }
}
