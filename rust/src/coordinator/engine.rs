//! The coordinator engine: executes planned collective requests over the
//! simulated machine, with schedule caching, optional XLA-backed ⊕, data
//! validation, and metrics — the service layer behind the `cbcast` CLI
//! and the benchmark drivers.

use std::sync::Arc;
use std::time::Instant;

use crate::collectives::baselines;
use crate::collectives::{
    allgatherv_sim, allreduce_sim, bcast_sim, reduce_scatter_sim, reduce_sim, ReduceOp, SumOp,
};
use crate::schedule::ScheduleCache;
use crate::sim::cost::CostModel;
use crate::sim::network::RunStats;

use super::metrics::Metrics;
use super::planner::{plan, Algo, Kind, Plan, Request, TuningParams};

#[cfg(test)]
use super::planner::Dist;

/// What the engine reports per request.
#[derive(Debug, Clone)]
pub struct Report {
    pub plan: Plan,
    pub stats: RunStats,
    /// Wall-clock of the whole simulated run (schedule computation +
    /// simulation + validation), seconds.
    pub wall: f64,
    /// Simulated completion time under the chosen cost model, seconds.
    pub sim_time: f64,
    /// Payload checksum validation outcome.
    pub valid: bool,
}

/// The engine. Owns the schedule cache and metrics; cost model and ⊕ are
/// per-call so benches can sweep them.
pub struct Engine {
    pub cache: Arc<ScheduleCache>,
    pub metrics: Metrics,
    pub tuning: TuningParams,
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine {
    pub fn new() -> Self {
        Engine {
            cache: Arc::new(ScheduleCache::new()),
            metrics: Metrics::new(),
            tuning: TuningParams::default(),
        }
    }

    /// Execute one request with element type i64 and SumOp (the generic
    /// driver used by the CLI; benches use the typed entry points below).
    pub fn run(&self, req: &Request, cost: &dyn CostModel) -> anyhow::Result<Report> {
        self.run_with_op(req, cost, Arc::new(SumOp))
    }

    /// Execute one request with a caller-chosen reduction operator.
    pub fn run_with_op(
        &self,
        req: &Request,
        cost: &dyn CostModel,
        op: Arc<dyn ReduceOp<i64>>,
    ) -> anyhow::Result<Report> {
        let t0 = Instant::now();
        let pl = plan(req, &self.tuning);
        let p = req.p;
        let (stats, valid) = match (req.kind, req.algo) {
            (Kind::Bcast, Algo::Circulant) => {
                let data = test_pattern(req.m, 1);
                let res = bcast_sim(p, req.root, &data, pl.n, req.elem_bytes, cost)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                let ok = res.buffers.iter().all(|b| b == &data);
                (res.stats, ok)
            }
            (Kind::Bcast, Algo::Binomial) => {
                let data = test_pattern(req.m, 1);
                let (stats, bufs) =
                    baselines::binomial_bcast_sim(p, req.root, &data, req.elem_bytes, cost)
                        .map_err(|e| anyhow::anyhow!("{e}"))?;
                (stats, bufs.iter().all(|b| b == &data))
            }
            (Kind::Bcast, Algo::VanDeGeijn) => {
                let data = test_pattern(req.m, 1);
                let (stats, bufs) =
                    baselines::vdg_bcast_sim(p, req.root, &data, req.elem_bytes, cost)
                        .map_err(|e| anyhow::anyhow!("{e}"))?;
                (stats, bufs.iter().all(|b| b == &data))
            }
            (Kind::Reduce, Algo::Circulant) => {
                let inputs: Vec<Vec<i64>> = (0..p).map(|r| test_pattern(req.m, r as i64)).collect();
                let expect = column_sums(&inputs);
                let res = reduce_sim(&inputs, req.root, pl.n, op, req.elem_bytes, cost)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                (res.stats, res.buffer == expect)
            }
            (Kind::Reduce, Algo::Binomial) => {
                let inputs: Vec<Vec<i64>> = (0..p).map(|r| test_pattern(req.m, r as i64)).collect();
                let expect = column_sums(&inputs);
                let (stats, buf) =
                    baselines::binomial_reduce_sim(&inputs, req.root, op, req.elem_bytes, cost)
                        .map_err(|e| anyhow::anyhow!("{e}"))?;
                (stats, buf == expect)
            }
            (Kind::Allgatherv, Algo::Circulant) => {
                let counts = req.dist.counts(p, req.m);
                let inputs = dist_inputs(&counts);
                let res = allgatherv_sim(&inputs, pl.n, req.elem_bytes, cost)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                let ok = res
                    .buffers
                    .iter()
                    .all(|rows| rows.iter().zip(&inputs).all(|(row, inp)| row == inp));
                (res.stats, ok)
            }
            (Kind::Allgatherv, Algo::Ring) => {
                let counts = req.dist.counts(p, req.m);
                let inputs = dist_inputs(&counts);
                let (stats, bufs) =
                    baselines::ring_allgatherv_sim(&inputs, req.elem_bytes, cost)
                        .map_err(|e| anyhow::anyhow!("{e}"))?;
                let ok = bufs
                    .iter()
                    .all(|rows| rows.iter().zip(&inputs).all(|(row, inp)| row == inp));
                (stats, ok)
            }
            (Kind::ReduceScatter, Algo::Circulant) => {
                let counts = req.dist.counts(p, req.m);
                let total: usize = counts.iter().sum();
                let inputs: Vec<Vec<i64>> =
                    (0..p).map(|r| test_pattern(total, r as i64)).collect();
                let sums = column_sums(&inputs);
                let res =
                    reduce_scatter_sim(&inputs, &counts, pl.n, op, req.elem_bytes, cost)
                        .map_err(|e| anyhow::anyhow!("{e}"))?;
                let ok = check_chunks(&res.chunks, &sums, &counts);
                (res.stats, ok)
            }
            (Kind::ReduceScatter, Algo::Ring) => {
                let counts = req.dist.counts(p, req.m);
                let total: usize = counts.iter().sum();
                let inputs: Vec<Vec<i64>> =
                    (0..p).map(|r| test_pattern(total, r as i64)).collect();
                let sums = column_sums(&inputs);
                let (stats, chunks) = baselines::ring_reduce_scatter_sim(
                    &inputs,
                    &counts,
                    op,
                    req.elem_bytes,
                    cost,
                )
                .map_err(|e| anyhow::anyhow!("{e}"))?;
                let ok = check_chunks(&chunks, &sums, &counts);
                (stats, ok)
            }
            (Kind::Allreduce, Algo::Circulant) => {
                let inputs: Vec<Vec<i64>> = (0..p).map(|r| test_pattern(req.m, r as i64)).collect();
                let expect = column_sums(&inputs);
                let res = allreduce_sim(&inputs, pl.n, op, req.elem_bytes, cost)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                let ok = res.buffers.iter().all(|b| b == &expect);
                let mut stats = res.rs_stats.clone();
                stats.rounds += res.ag_stats.rounds;
                stats.active_rounds += res.ag_stats.active_rounds;
                stats.messages += res.ag_stats.messages;
                stats.bytes += res.ag_stats.bytes;
                stats.time += res.ag_stats.time;
                (stats, ok)
            }
            (kind, algo) => {
                anyhow::bail!("unsupported combination: {kind:?} with {algo:?}")
            }
        };
        let wall = t0.elapsed().as_secs_f64();
        self.metrics.observe(&format!("{:?}", req.kind), stats.time, wall, valid);
        Ok(Report { plan: pl, sim_time: stats.time, stats, wall, valid })
    }
}

fn test_pattern(m: usize, seed: i64) -> Vec<i64> {
    (0..m as i64).map(|i| (seed * 31 + i * 7) % 1009).collect()
}

fn column_sums(inputs: &[Vec<i64>]) -> Vec<i64> {
    let m = inputs[0].len();
    (0..m).map(|i| inputs.iter().map(|v| v[i]).sum()).collect()
}

fn dist_inputs(counts: &[usize]) -> Vec<Vec<i64>> {
    counts
        .iter()
        .enumerate()
        .map(|(r, &c)| (0..c as i64).map(|i| (r as i64 * 131 + i) % 997).collect())
        .collect()
}

fn check_chunks(chunks: &[Vec<i64>], sums: &[i64], counts: &[usize]) -> bool {
    let mut off = 0usize;
    for (r, chunk) in chunks.iter().enumerate() {
        if chunk != &sums[off..off + counts[r]] {
            return false;
        }
        off += counts[r];
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cost::UnitCost;

    #[test]
    fn engine_runs_all_kinds_circulant() {
        let eng = Engine::new();
        for kind in [Kind::Bcast, Kind::Reduce, Kind::Allgatherv, Kind::ReduceScatter, Kind::Allreduce]
        {
            let mut req = Request::new(kind, 17, 1000);
            req.blocks = Some(4);
            let rep = eng.run(&req, &UnitCost).unwrap();
            assert!(rep.valid, "{kind:?} failed validation");
            assert!(rep.stats.messages > 0);
        }
    }

    #[test]
    fn engine_runs_baselines() {
        let eng = Engine::new();
        let combos = [
            (Kind::Bcast, Algo::Binomial),
            (Kind::Bcast, Algo::VanDeGeijn),
            (Kind::Reduce, Algo::Binomial),
            (Kind::Allgatherv, Algo::Ring),
            (Kind::ReduceScatter, Algo::Ring),
        ];
        for (kind, algo) in combos {
            let mut req = Request::new(kind, 12, 600);
            req.algo = algo;
            let rep = eng.run(&req, &UnitCost).unwrap();
            assert!(rep.valid, "{kind:?}/{algo:?} failed validation");
        }
    }

    #[test]
    fn engine_distributions() {
        let eng = Engine::new();
        for dist in [Dist::Regular, Dist::Irregular, Dist::Degenerate] {
            let mut req = Request::new(Kind::Allgatherv, 9, 900);
            req.dist = dist;
            req.blocks = Some(3);
            let rep = eng.run(&req, &UnitCost).unwrap();
            assert!(rep.valid, "{dist:?}");
        }
    }

    #[test]
    fn engine_rejects_unsupported() {
        let eng = Engine::new();
        let mut req = Request::new(Kind::Allgatherv, 9, 900);
        req.algo = Algo::Binomial;
        assert!(eng.run(&req, &UnitCost).is_err());
    }

    #[test]
    fn metrics_accumulate() {
        let eng = Engine::new();
        let mut req = Request::new(Kind::Bcast, 9, 100);
        req.blocks = Some(2);
        for _ in 0..3 {
            eng.run(&req, &UnitCost).unwrap();
        }
        let text = eng.metrics.render();
        assert!(text.contains("Bcast"), "{text}");
        assert!(text.contains("count=3"), "{text}");
    }
}
