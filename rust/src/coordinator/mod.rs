//! The coordinator: the service layer that plans and executes collective
//! requests (the role of an MPI library's collective framework) — request
//! vocabulary and tuning decisions in [`planner`], execution with schedule
//! caching and validation in [`engine`], observability in [`metrics`].

pub mod engine;
pub mod metrics;
pub mod planner;

pub use engine::{Engine, Report};
pub use metrics::Metrics;
pub use planner::{parse_cost, plan, Algo, Dist, Kind, Plan, Request, TuningParams};
