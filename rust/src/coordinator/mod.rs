//! The coordinator: the service layer that plans and executes collective
//! requests (the role of an MPI library's collective framework) — request
//! vocabulary and tuning decisions in [`planner`], execution in [`engine`]
//! as a thin layer over [`crate::comm::Communicator`] (which owns the
//! schedule caching), observability in [`metrics`]. The typed
//! [`Kind`]/[`Algo`] enums live in [`crate::comm`] and are re-exported
//! here; string parsing survives only at the CLI edge.

pub mod engine;
pub mod metrics;
pub mod planner;

pub use engine::{Engine, Report};
pub use metrics::Metrics;
pub use planner::{parse_cost, plan, Algo, Dist, Kind, Plan, Request, TuningParams};
