//! Request vocabulary and planning: which algorithm, how many blocks,
//! which cost model — the decisions an MPI library's tuned module makes,
//! centralised and inspectable.

use crate::collectives::tuning;
use crate::schedule::ceil_log2;
use crate::sim::cost::{CostModel, HierarchicalCost, LinearCost, UnitCost};

/// The collective operations the engine serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Bcast,
    Reduce,
    Allgatherv,
    ReduceScatter,
    Allreduce,
}

impl Kind {
    pub fn parse(s: &str) -> Option<Kind> {
        Some(match s {
            "bcast" => Kind::Bcast,
            "reduce" => Kind::Reduce,
            "allgatherv" | "allgather" => Kind::Allgatherv,
            "reduce-scatter" | "reduce_scatter" => Kind::ReduceScatter,
            "allreduce" => Kind::Allreduce,
            _ => return None,
        })
    }
}

/// Algorithm family to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    /// The paper's circulant-schedule pipelined algorithms.
    Circulant,
    /// Binomial tree (bcast/reduce) — the native small-message algorithm.
    Binomial,
    /// van de Geijn scatter+allgather (bcast) — native large-message.
    VanDeGeijn,
    /// Ring (allgatherv / reduce-scatter) — native large-message.
    Ring,
}

impl Algo {
    pub fn parse(s: &str) -> Option<Algo> {
        Some(match s {
            "circulant" | "new" => Algo::Circulant,
            "binomial" => Algo::Binomial,
            "vdg" | "native-large" => Algo::VanDeGeijn,
            "ring" => Algo::Ring,
            _ => return None,
        })
    }
}

/// Input distribution for the irregular collectives (Fig. 2's problems).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dist {
    /// `m/p` everywhere.
    Regular,
    /// `(i mod 3) * m/p` for rank `i`.
    Irregular,
    /// rank 0 holds all `m`, everyone else nothing.
    Degenerate,
}

impl Dist {
    pub fn parse(s: &str) -> Option<Dist> {
        Some(match s {
            "regular" => Dist::Regular,
            "irregular" => Dist::Irregular,
            "degenerate" => Dist::Degenerate,
            _ => return None,
        })
    }

    /// Per-rank element counts for total volume `m` over `p` ranks.
    pub fn counts(&self, p: usize, m: usize) -> Vec<usize> {
        match self {
            Dist::Regular => {
                let base = m / p;
                let rem = m % p;
                (0..p).map(|i| base + usize::from(i < rem)).collect()
            }
            Dist::Irregular => {
                let unit = m / p;
                let mut c: Vec<usize> = (0..p).map(|i| (i % 3) * unit).collect();
                // Put the remainder volume on rank 0 so totals stay m-ish.
                let used: usize = c.iter().sum();
                if used < m {
                    c[0] += m - used;
                }
                c
            }
            Dist::Degenerate => {
                let mut c = vec![0usize; p];
                c[0] = m;
                c
            }
        }
    }
}

/// One collective request.
#[derive(Debug, Clone)]
pub struct Request {
    pub kind: Kind,
    pub p: usize,
    /// Total elements (bcast/reduce: buffer length; allgatherv /
    /// reduce-scatter: sum over ranks).
    pub m: usize,
    pub root: usize,
    pub elem_bytes: usize,
    /// None = auto-tune via the paper's rule.
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub dist: Dist,
}

impl Request {
    pub fn new(kind: Kind, p: usize, m: usize) -> Self {
        Request {
            kind,
            p,
            m,
            root: 0,
            elem_bytes: 4,
            blocks: None,
            algo: Algo::Circulant,
            dist: Dist::Regular,
        }
    }
}

/// The planner's output: everything the engine needs to run the request.
#[derive(Debug, Clone)]
pub struct Plan {
    pub n: usize,
    pub q: usize,
    pub predicted_rounds: usize,
}

/// Tuning constants (the paper's F and G, plus α/β for the model rule).
#[derive(Debug, Clone)]
pub struct TuningParams {
    pub f_const: f64,
    pub g_const: f64,
}

impl Default for TuningParams {
    fn default() -> Self {
        // The paper's experimentally chosen constants (Fig. 1: F = 70,
        // Fig. 2: G = 40).
        TuningParams { f_const: 70.0, g_const: 40.0 }
    }
}

/// Choose the block count and predict the round count for a request.
pub fn plan(req: &Request, tp: &TuningParams) -> Plan {
    let q = ceil_log2(req.p.max(1));
    let n = req.blocks.unwrap_or_else(|| match req.kind {
        Kind::Bcast | Kind::Reduce => tuning::bcast_blocks_paper(req.m, req.p, tp.f_const),
        Kind::Allgatherv | Kind::ReduceScatter | Kind::Allreduce => {
            tuning::allgatherv_blocks_paper(req.m, req.p, tp.g_const)
        }
    });
    let n = n.max(1);
    let rounds = if req.p <= 1 {
        0
    } else {
        match req.algo {
            Algo::Circulant => match req.kind {
                Kind::Allreduce => 2 * (n - 1 + q),
                _ => n - 1 + q,
            },
            Algo::Binomial => q,
            Algo::VanDeGeijn => q + req.p - 1,
            Algo::Ring => req.p - 1,
        }
    };
    Plan { n, q, predicted_rounds: rounds }
}

/// Parse a cost-model spec: `unit`, `linear[:alpha:beta]`,
/// `vega:<cores>`, `cluster:<cores>`.
pub fn parse_cost(spec: &str) -> Option<Box<dyn CostModel>> {
    let parts: Vec<&str> = spec.split(':').collect();
    match parts[0] {
        "unit" => Some(Box::new(UnitCost)),
        "linear" => {
            if parts.len() == 3 {
                let alpha = parts[1].parse().ok()?;
                let beta = parts[2].parse().ok()?;
                Some(Box::new(LinearCost::new(alpha, beta)))
            } else {
                Some(Box::new(LinearCost::hpc_default()))
            }
        }
        "vega" => {
            let cores = parts.get(1)?.parse().ok()?;
            Some(Box::new(HierarchicalCost::vega(cores)))
        }
        "cluster" => {
            let cores = parts.get(1)?.parse().ok()?;
            Some(Box::new(HierarchicalCost::small_cluster(cores)))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_counts_sum() {
        for p in [4usize, 9, 17] {
            for m in [0usize, 100, 1001] {
                let reg = Dist::Regular.counts(p, m);
                assert_eq!(reg.iter().sum::<usize>(), m);
                let deg = Dist::Degenerate.counts(p, m);
                assert_eq!(deg.iter().sum::<usize>(), m);
                assert_eq!(deg[0], m);
                let irr = Dist::Irregular.counts(p, m);
                // Irregular sums to m when p >= 3 (remainder goes to 0).
                if m >= p {
                    assert_eq!(irr.iter().sum::<usize>(), m, "p={p} m={m}");
                }
            }
        }
    }

    #[test]
    fn plan_rounds_match_theory() {
        let mut req = Request::new(Kind::Bcast, 17, 10000);
        req.blocks = Some(13);
        let pl = plan(&req, &TuningParams::default());
        assert_eq!(pl.q, 5);
        assert_eq!(pl.predicted_rounds, 13 - 1 + 5);

        req.algo = Algo::Binomial;
        assert_eq!(plan(&req, &TuningParams::default()).predicted_rounds, 5);

        req.algo = Algo::VanDeGeijn;
        assert_eq!(plan(&req, &TuningParams::default()).predicted_rounds, 5 + 16);
    }

    #[test]
    fn parse_cost_specs() {
        assert!(parse_cost("unit").is_some());
        assert!(parse_cost("linear").is_some());
        assert!(parse_cost("linear:1e-6:1e-10").is_some());
        assert!(parse_cost("vega:128").is_some());
        assert!(parse_cost("cluster:32").is_some());
        assert!(parse_cost("bogus").is_none());
        assert!(parse_cost("vega").is_none());
    }

    #[test]
    fn kind_algo_parse() {
        assert_eq!(Kind::parse("bcast"), Some(Kind::Bcast));
        assert_eq!(Kind::parse("reduce-scatter"), Some(Kind::ReduceScatter));
        assert_eq!(Algo::parse("new"), Some(Algo::Circulant));
        assert!(Kind::parse("nope").is_none());
    }
}
