//! Request vocabulary and planning: which algorithm, how many blocks,
//! which cost model — the decisions an MPI library's tuned module makes,
//! centralised and inspectable.
//!
//! The typed [`Kind`]/[`Algo`] enums (and the [`TuningParams`] block-count
//! constants) live in [`crate::comm`] — the coordinator re-exports them
//! and plans *over* them; it no longer owns a parallel copy of the
//! algorithm-selection logic.

use crate::schedule::ceil_log2;
use crate::sim::cost::{CostModel, HierarchicalCost, LinearCost, UnitCost};

pub use crate::comm::{Algo, Kind, TuningParams};

/// Input distribution for the irregular collectives (Fig. 2's problems).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dist {
    /// `m/p` everywhere.
    Regular,
    /// `(i mod 3) * m/p` for rank `i`.
    Irregular,
    /// rank 0 holds all `m`, everyone else nothing.
    Degenerate,
}

impl Dist {
    pub fn parse(s: &str) -> Option<Dist> {
        Some(match s {
            "regular" => Dist::Regular,
            "irregular" => Dist::Irregular,
            "degenerate" => Dist::Degenerate,
            _ => return None,
        })
    }

    /// Per-rank element counts for total volume `m` over `p` ranks.
    pub fn counts(&self, p: usize, m: usize) -> Vec<usize> {
        match self {
            Dist::Regular => {
                let base = m / p;
                let rem = m % p;
                (0..p).map(|i| base + usize::from(i < rem)).collect()
            }
            Dist::Irregular => {
                let unit = m / p;
                let mut c: Vec<usize> = (0..p).map(|i| (i % 3) * unit).collect();
                // Put the remainder volume on rank 0 so totals stay m-ish.
                let used: usize = c.iter().sum();
                if used < m {
                    c[0] += m - used;
                }
                c
            }
            Dist::Degenerate => {
                let mut c = vec![0usize; p];
                c[0] = m;
                c
            }
        }
    }
}

/// One collective request.
#[derive(Debug, Clone)]
pub struct Request {
    pub kind: Kind,
    pub p: usize,
    /// Total elements (bcast/reduce: buffer length; allgatherv /
    /// reduce-scatter: sum over ranks).
    pub m: usize,
    pub root: usize,
    pub elem_bytes: usize,
    /// None = auto-tune via the paper's rule.
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub dist: Dist,
}

impl Request {
    pub fn new(kind: Kind, p: usize, m: usize) -> Self {
        Request {
            kind,
            p,
            m,
            root: 0,
            elem_bytes: 4,
            blocks: None,
            algo: Algo::Circulant,
            dist: Dist::Regular,
        }
    }
}

/// The planner's output: everything the engine needs to run the request.
#[derive(Debug, Clone)]
pub struct Plan {
    pub n: usize,
    pub q: usize,
    /// The algorithm after [`Algo::Auto`] resolution.
    pub algo: Algo,
    pub predicted_rounds: usize,
}

/// Choose the block count, resolve the algorithm and predict the round
/// count for a request.
pub fn plan(req: &Request, tp: &TuningParams) -> Plan {
    let q = ceil_log2(req.p.max(1));
    // The same rule a Communicator applies — one definition, two callers.
    let n = crate::comm::resolve_blocks(req.kind, req.p, req.m, tp, req.blocks);
    let algo = req.algo.resolve_with(req.kind, req.p, req.m, req.elem_bytes, req.blocks, tp);
    let rounds = if req.p <= 1 {
        0
    } else {
        match algo {
            Algo::Circulant => match req.kind {
                Kind::Allreduce => 2 * (n - 1 + q),
                _ => n - 1 + q,
            },
            Algo::Binomial => q,
            Algo::VanDeGeijn => q + req.p - 1,
            Algo::Ring => match req.kind {
                Kind::Allreduce => 2 * (req.p - 1),
                _ => req.p - 1,
            },
            // Recursive halving: ⌊log2 p⌋ halving rounds, plus one fold
            // and one unfold round for non-powers-of-two.
            Algo::RecursiveHalving => {
                if req.p.is_power_of_two() {
                    q
                } else {
                    q + 1
                }
            }
            // The Karp tree's depth depends on the LogP parameters it was
            // built against; rebuild the (cheap) tree to read its height.
            Algo::OptTree => {
                let params = tp.logp.unwrap_or_default().scaled_for(req.m * req.elem_bytes);
                let rounds = crate::schedule::OptTree::build(req.p, &params).rounds();
                match req.kind {
                    Kind::Allreduce => 2 * rounds,
                    _ => rounds,
                }
            }
            Algo::Auto => unreachable!("resolve() never returns Auto"),
        }
    };
    Plan { n, q, algo, predicted_rounds: rounds }
}

/// Parse a cost-model spec: `unit`, `linear[:alpha:beta]`,
/// `vega:<cores>`, `cluster:<cores>`.
pub fn parse_cost(spec: &str) -> Option<Box<dyn CostModel>> {
    let parts: Vec<&str> = spec.split(':').collect();
    match parts[0] {
        "unit" => Some(Box::new(UnitCost)),
        "linear" => {
            if parts.len() == 3 {
                let alpha = parts[1].parse().ok()?;
                let beta = parts[2].parse().ok()?;
                Some(Box::new(LinearCost::new(alpha, beta)))
            } else {
                Some(Box::new(LinearCost::hpc_default()))
            }
        }
        "vega" => {
            let cores = parts.get(1)?.parse().ok()?;
            Some(Box::new(HierarchicalCost::vega(cores)))
        }
        "cluster" => {
            let cores = parts.get(1)?.parse().ok()?;
            Some(Box::new(HierarchicalCost::small_cluster(cores)))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_counts_sum() {
        for p in [4usize, 9, 17] {
            for m in [0usize, 100, 1001] {
                let reg = Dist::Regular.counts(p, m);
                assert_eq!(reg.iter().sum::<usize>(), m);
                let deg = Dist::Degenerate.counts(p, m);
                assert_eq!(deg.iter().sum::<usize>(), m);
                assert_eq!(deg[0], m);
                let irr = Dist::Irregular.counts(p, m);
                // Irregular sums to m when p >= 3 (remainder goes to 0).
                if m >= p {
                    assert_eq!(irr.iter().sum::<usize>(), m, "p={p} m={m}");
                }
            }
        }
    }

    #[test]
    fn plan_rounds_match_theory() {
        let mut req = Request::new(Kind::Bcast, 17, 10000);
        req.blocks = Some(13);
        let pl = plan(&req, &TuningParams::default());
        assert_eq!(pl.q, 5);
        assert_eq!(pl.algo, Algo::Circulant);
        assert_eq!(pl.predicted_rounds, 13 - 1 + 5);

        req.algo = Algo::Binomial;
        assert_eq!(plan(&req, &TuningParams::default()).predicted_rounds, 5);

        req.algo = Algo::VanDeGeijn;
        assert_eq!(plan(&req, &TuningParams::default()).predicted_rounds, 5 + 16);
    }

    #[test]
    fn plan_resolves_auto() {
        // Large payload → circulant pipeline; small → binomial.
        let mut req = Request::new(Kind::Bcast, 17, 1 << 20);
        req.algo = Algo::Auto;
        let pl = plan(&req, &TuningParams::default());
        assert_eq!(pl.algo, Algo::Circulant);
        assert_eq!(pl.predicted_rounds, pl.n - 1 + pl.q);

        let mut small = Request::new(Kind::Bcast, 17, 64);
        small.algo = Algo::Auto;
        let pl = plan(&small, &TuningParams::default());
        assert_eq!(pl.algo, Algo::Binomial);
        assert_eq!(pl.predicted_rounds, pl.q);
    }

    #[test]
    fn parse_cost_specs() {
        assert!(parse_cost("unit").is_some());
        assert!(parse_cost("linear").is_some());
        assert!(parse_cost("linear:1e-6:1e-10").is_some());
        assert!(parse_cost("vega:128").is_some());
        assert!(parse_cost("cluster:32").is_some());
        assert!(parse_cost("bogus").is_none());
        assert!(parse_cost("vega").is_none());
    }

    #[test]
    fn kind_algo_reexported() {
        // The enums live in `comm`; the coordinator path keeps working.
        assert_eq!(Kind::parse("bcast"), Some(Kind::Bcast));
        assert_eq!(Algo::parse("new"), Some(Algo::Circulant));
        assert!(Kind::parse("nope").is_none());
    }
}
