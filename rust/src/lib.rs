//! # circulant-bcast
//!
//! Production-quality reproduction of *“Optimal Broadcast Schedules in
//! Logarithmic Time with Applications to Broadcast, All-Broadcast,
//! Reduction and All-Reduction”* (J. L. Träff, 2024).
//!
//! The library provides, in three layers:
//!
//! * [`schedule`] — the paper's core contribution: round-optimal broadcast
//!   schedules on `ceil(log2 p)`-regular circulant graphs, computed in
//!   **O(log p)** time per processor (Algorithms 2–6, Theorems 2–3), plus
//!   old-style baselines, the doubling constructions, an exhaustive
//!   verifier and a schedule cache.
//! * [`sim`] — the machine substrate: a fully-connected, one-ported,
//!   send/receive-bidirectional, round-based message-passing simulator
//!   with linear and hierarchical α-β cost models, and a threaded runtime
//!   where every simulated rank is an OS thread.
//! * [`collectives`] — the MPI-style collectives built on the schedules:
//!   pipelined broadcast (Algorithm 1), all-broadcast/allgatherv
//!   (Algorithm 7), reduction and all-reduction via reversed schedules
//!   (Observation 1), their classical baselines (binomial, ring,
//!   recursive-doubling, van-de-Geijn-style), and block-count tuning.
//! * [`runtime`] — the PJRT bridge: AOT-compiled XLA artifacts (authored
//!   in JAX/Pallas at build time, `artifacts/*.hlo.txt`) loaded and
//!   executed from Rust for the reduction operator hot path.
//! * [`coordinator`] — the service layer tying it together: planner,
//!   engine, metrics, request loop (used by the `cbcast` CLI).
//! * [`testkit`] — a tiny property-testing harness (offline substitute for
//!   `proptest`).

pub mod collectives;
pub mod coordinator;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod testkit;
