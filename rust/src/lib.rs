//! # circulant-bcast
//!
//! Production-quality reproduction of *“Optimal Broadcast Schedules in
//! Logarithmic Time with Applications to Broadcast, All-Broadcast,
//! Reduction and All-Reduction”* (J. L. Träff, 2024).
//!
//! ## The front door: [`comm::Communicator`]
//!
//! The paper's Observation 1 is that one schedule family serves all four
//! collectives; the API mirrors that. Build a [`comm::Communicator`] once
//! per processor count `p` and issue every collective through it — the
//! handle owns the circulant skip table, a shared schedule cache (one
//! entry per *relative* rank, so repeated calls and varying roots never
//! recompute), a pluggable execution backend and a cost model:
//!
//! ```no_run
//! use std::sync::Arc;
//! use circulant_bcast::comm::{AllreduceReq, BcastReq, Communicator};
//! use circulant_bcast::collectives::SumOp;
//!
//! let comm = Communicator::new(1000);              // once
//! let data: Vec<i64> = (0..1 << 16).collect();
//! let out = comm.bcast(BcastReq::new(0, &data))?;  // many times
//! assert!(out.all_received());
//!
//! let grads: Vec<Vec<f32>> = (0..1000).map(|_| vec![1.0; 4096]).collect();
//! let sum = comm.allreduce(AllreduceReq::new(&grads, Arc::new(SumOp)))?;
//! # Ok::<(), circulant_bcast::comm::CommError>(())
//! ```
//!
//! Typed requests select the algorithm ([`comm::Algo`], with an `Auto`
//! variant driven by the paper's §3 tuning rules) and optionally override
//! the block count; every collective returns the same [`comm::Outcome`]
//! (stats, buffers, resolved algorithm, rounds).
//!
//! For concurrent workloads, [`comm::Communicator::traffic`] opens a
//! nonblocking batch: typed `I*Req` submissions return [`comm::Pending`]
//! handles and [`comm::TrafficEngine::run`] executes the whole batch
//! overlapped — disjoint rank windows truly concurrent, shared ranks
//! round-interleaved under a cross-operation one-ported port ledger,
//! with every per-op outcome bit-identical to a solo run (see
//! [`comm::traffic`]).
//!
//! For the paper's own programming model — each processor computes its
//! O(log p) schedule independently, with no communication — the SPMD
//! rank plane ([`comm::rank`]) provides per-rank [`comm::RankComm`]
//! handles over a pluggable [`comm::Transport`] (a real
//! one-thread-per-rank runtime, or a lockstep replay), and
//! [`comm::BackendKind::Spmd`] runs the god-view API on top of it.
//! The wire plane carries the same rank plane across real OS sockets
//! ([`comm::SocketTransport`], [`comm::BackendKind::Socket`]), and the
//! [`service`] module builds a long-lived collective daemon with
//! admission control on top of the same framing (the `cbcastd`
//! binary).
//!
//! ## Layers underneath
//!
//! * [`schedule`] — the paper's core contribution: round-optimal broadcast
//!   schedules on `ceil(log2 p)`-regular circulant graphs, computed in
//!   **O(log p)** time per processor (Algorithms 2–6, Theorems 2–3), plus
//!   old-style baselines, the doubling constructions, an exhaustive
//!   verifier and the communicator-style schedule cache.
//! * [`sim`] — the machine substrate: a fully-connected, one-ported,
//!   send/receive-bidirectional, round-based message-passing simulator
//!   with linear and hierarchical α-β cost models, and a threaded runtime
//!   where every simulated rank is an OS thread (both are
//!   [`comm::ExecBackend`]s).
//! * [`collectives`] — the per-rank state machines behind the
//!   `Communicator` methods: pipelined broadcast (Algorithm 1),
//!   all-broadcast/allgatherv (Algorithm 7), reduction and all-reduction
//!   via reversed schedules (Observation 1), their classical baselines
//!   (binomial, ring, recursive-doubling, van-de-Geijn-style), and
//!   block-count tuning. (The legacy `*_sim` free functions finished
//!   their deprecation cycle and were removed — use a `Communicator`.)
//! * [`runtime`] — the PJRT bridge: AOT-compiled XLA artifacts (authored
//!   in JAX/Pallas at build time, `artifacts/*.hlo.txt`) loaded and
//!   executed from Rust for the reduction operator hot path (gated behind
//!   the `xla` cargo feature; a graceful stub compiles in otherwise).
//! * [`coordinator`] — the service layer: planner, metrics, request loop
//!   (used by the `cbcast` CLI), with execution delegated to [`comm`].
//! * [`service`] — the collective service daemon over the wire plane:
//!   concurrent tenant connections, bounded admission into shared
//!   traffic-plane batches, per-tenant usage accounting (the `cbcastd`
//!   binary).
//! * [`testkit`] — a tiny property-testing harness (offline substitute for
//!   `proptest`).

pub mod collectives;
pub mod comm;
pub mod coordinator;
pub mod runtime;
pub mod schedule;
pub mod service;
pub mod sim;
pub mod testkit;
