//! `cbcastd` — the collective service daemon and its workload client.
//!
//! ```text
//! cbcastd serve    (--uds PATH | --tcp ADDR) [-p N] [--queue-cap N]
//!                  [--batch-max N] [--threads N] [--gather-ms N]
//!                  [--retry-after-ms N] [--client-timeout-ms N]
//!                  [--chaos-drop N] [--chaos-dup N] [--chaos-reorder N]
//!                  [--chaos-delay N] [--chaos-corrupt N] [--chaos-seed S]
//! cbcastd client   (--uds PATH | --tcp ADDR) [--tenant NAME] [--ops N]
//!                  [--seed S] [--verify]
//! cbcastd stats    (--uds PATH | --tcp ADDR)
//! cbcastd shutdown (--uds PATH | --tcp ADDR)
//! cbcastd rank     --dir DIR --rank R -p N [--world-id W] [--m M]
//!                  [--root R0] [--blocks B] [--seed S] [--crash-after K]
//!                  [--timeout-ms T] [--max-shrinks S]
//! ```
//!
//! `serve` binds, then blocks until a client sends the administrative
//! shutdown frame. The `--chaos-*` flags (rates per 10 000 frames,
//! `--chaos-delay` capped at 5 ms, `--chaos-corrupt` flipping 3 bits)
//! assemble a seeded frame-level fault plan the daemon self-probes at
//! startup over a chaos-socket world: a plan the protocol-v3
//! reliability layer cannot heal refuses to serve, a healable one
//! starts normally and its healed faults show on the stats/stop lines.
//!
//! `client` generates a seeded traffic mix
//! (`TESTKIT_SEED` conventions do not apply here — pass `--seed`),
//! submits every op with reject-and-retry, and prints one summary line;
//! with `--verify` it also recomputes each op solo and asserts the
//! daemon's digest + statistics match bit-for-bit. Exit codes: 0 ok,
//! 1 failure, 2 usage.
//!
//! `rank` is one rank of a **multi-process elastic world** — the
//! process-granular analogue of the in-process recovery suite
//! (`tests/recovery.rs`). Launch `p` of them against a shared `--dir`;
//! they rendezvous over UDS (`uds_world_epoch`), broadcast a seeded
//! payload, and print `rank R OK epoch E p P digest D`. Give exactly
//! one of them `--crash-after K`: that process dies at round `K`
//! **without closing its sockets** (`abort()` skips destructors), the
//! survivors read EOF-without-BYE on their direct links, agree on the
//! dead rank with no coordinator, rebuild a (p−1)-rank world under
//! `--dir/epoch-1` with the epoch-stamped handshake, and rerun — so
//! all survivors print the same digest at `epoch 1 p {p-1}`. The CI
//! `recovery-smoke` job drives this at p = 64 with a real kill.
//!
//! (Hand-rolled argument parsing: the image has no network access and
//! the vendored crate set does not include clap.)

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use circulant_bcast::comm::{
    CommBuilder, CrashAfter, FaultPlan, Membership, RankComm, SocketTransport, Transport,
};
use circulant_bcast::schedule::Skips;
use circulant_bcast::service::{
    serve_tcp, serve_unix, summarize, ServiceClient, ServiceConfig, ServiceReply,
};
use circulant_bcast::testkit::{run_mix_blocking, traffic_mix, MixOptions, Rng};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("serve") => cmd_serve(&args[1..]),
        Some("client") => cmd_client(&args[1..]),
        Some("stats") => cmd_stats(&args[1..]),
        Some("shutdown") => cmd_shutdown(&args[1..]),
        Some("rank") => cmd_rank(&args[1..]),
        Some("help") | None => {
            print_help();
            0
        }
        Some(other) => {
            eprintln!("unknown command {other:?}; try `cbcastd help`");
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!("cbcastd — long-lived collective service daemon (circulant schedules, Träff 2024)");
    println!("commands: serve, client, stats, shutdown, rank, help");
    println!("see the header of rust/src/bin/cbcastd.rs or README.md for options");
}

/// Tiny flag parser: returns the value following `name`.
fn opt<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).map(|s| s.as_str())
}

fn opt_usize(args: &[String], name: &str, default: usize) -> usize {
    opt(args, name).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn opt_u64(args: &[String], name: &str, default: u64) -> u64 {
    opt(args, name).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn connect(args: &[String], tenant: &str) -> Result<ServiceClient, i32> {
    let client = if let Some(path) = opt(args, "--uds") {
        ServiceClient::connect_unix_retry(Path::new(path), tenant, Duration::from_secs(10))
    } else if let Some(addr) = opt(args, "--tcp") {
        ServiceClient::connect_tcp(addr, tenant)
    } else {
        eprintln!("need --uds PATH or --tcp ADDR");
        return Err(2);
    };
    client.map_err(|e| {
        eprintln!("connect failed: {e}");
        1
    })
}

fn cmd_serve(args: &[String]) -> i32 {
    let mut cfg = ServiceConfig {
        p: opt_usize(args, "-p", 32),
        queue_cap: opt_usize(args, "--queue-cap", 128),
        batch_max: opt_usize(args, "--batch-max", 64),
        ..ServiceConfig::default()
    };
    cfg.gather = Duration::from_millis(opt_u64(args, "--gather-ms", 2));
    cfg.retry_after = Duration::from_millis(opt_u64(args, "--retry-after-ms", 5));
    cfg.client_timeout = Duration::from_millis(opt_u64(args, "--client-timeout-ms", 2000));
    if let Some(t) = opt(args, "--threads").and_then(|v| v.parse().ok()) {
        cfg.threads = Some(t);
    }
    // Chaos knob: rates are per 10 000 frames; any non-zero rate arms
    // the seeded plan (and the startup self-probe behind it).
    let drop = opt_u64(args, "--chaos-drop", 0) as u32;
    let dup = opt_u64(args, "--chaos-dup", 0) as u32;
    let reorder = opt_u64(args, "--chaos-reorder", 0) as u32;
    let delay = opt_u64(args, "--chaos-delay", 0) as u32;
    let corrupt = opt_u64(args, "--chaos-corrupt", 0) as u32;
    if drop + dup + reorder + delay + corrupt > 0 {
        let mut plan = FaultPlan::new(opt_u64(args, "--chaos-seed", 1).max(1));
        if drop > 0 {
            plan = plan.drop_per_10k(drop);
        }
        if dup > 0 {
            plan = plan.dup_per_10k(dup);
        }
        if reorder > 0 {
            plan = plan.reorder_per_10k(reorder);
        }
        if delay > 0 {
            plan = plan.delay_per_10k(delay, 5);
        }
        if corrupt > 0 {
            plan = plan.corrupt_per_10k(corrupt, 3);
        }
        cfg.chaos = Some(plan);
    }

    let handle = if let Some(path) = opt(args, "--uds") {
        serve_unix(Path::new(path), cfg)
    } else if let Some(addr) = opt(args, "--tcp") {
        serve_tcp(addr, cfg)
    } else {
        eprintln!("need --uds PATH or --tcp ADDR");
        return 2;
    };
    let handle = match handle {
        Ok(h) => h,
        Err(e) => {
            eprintln!("bind failed: {e}");
            return 1;
        }
    };
    match handle.addr() {
        Some(addr) => println!("cbcastd: serving p={} on tcp {addr}", handle.p()),
        None => println!("cbcastd: serving p={} on uds", handle.p()),
    }
    // Blocks until a client sends the administrative shutdown frame.
    let metrics = handle.join();
    println!(
        "cbcastd: stopped after {} batches ({} ops ok, {} failed, {} rejections, {} dropped) \
         wire: {}",
        metrics.batches,
        metrics.completed,
        metrics.failed,
        metrics.rejected,
        metrics.dropped,
        metrics.wire,
    );
    0
}

fn cmd_client(args: &[String]) -> i32 {
    let tenant = opt(args, "--tenant").unwrap_or("default");
    let n_ops = opt_usize(args, "--ops", 16);
    let seed = opt_u64(args, "--seed", 1);
    let verify = has_flag(args, "--verify");

    let mut client = match connect(args, tenant) {
        Ok(c) => c,
        Err(code) => return code,
    };
    let p = client.p();
    let mix = traffic_mix(&mut Rng::new(seed.max(1)), p, n_ops, &MixOptions::default());

    let start = Instant::now();
    let (mut ok, mut failed, mut rejections) = (0usize, 0usize, 0usize);
    let mut latencies_ms: Vec<f64> = Vec::with_capacity(n_ops);
    for (i, op) in mix.ops.iter().enumerate() {
        let op_start = Instant::now();
        // Count refusals ourselves (call_admitted would hide them).
        let reply = loop {
            match client.call(i as u64, op) {
                Ok(ServiceReply::Rejected { retry_after_ms }) => {
                    rejections += 1;
                    std::thread::sleep(Duration::from_millis(retry_after_ms.max(1) as u64));
                }
                Ok(reply) => break reply,
                Err(e) => {
                    eprintln!("tenant {tenant}: op #{i} transport error: {e}");
                    return 1;
                }
            }
        };
        latencies_ms.push(op_start.elapsed().as_secs_f64() * 1e3);
        match reply {
            ServiceReply::Ok(got) => {
                ok += 1;
                if verify {
                    let solo = run_mix_blocking(&CommBuilder::new(op.ranks(p)).build(), op);
                    if summarize(&solo) != Ok(got.clone()) {
                        eprintln!(
                            "tenant {tenant}: op #{i} diverged from solo run\n  daemon: {got:?}\n  solo:   {:?}",
                            summarize(&solo)
                        );
                        return 1;
                    }
                }
            }
            ServiceReply::Err(msg) => {
                failed += 1;
                if verify {
                    let solo = run_mix_blocking(&CommBuilder::new(op.ranks(p)).build(), op);
                    if summarize(&solo) != Err(msg.clone()) {
                        eprintln!(
                            "tenant {tenant}: op #{i} failed differently from solo run\n  daemon: {msg}\n  solo:   {:?}",
                            summarize(&solo)
                        );
                        return 1;
                    }
                }
            }
            ServiceReply::Rejected { .. } => unreachable!("handled in the retry loop"),
        }
    }
    let elapsed = start.elapsed().as_secs_f64();
    latencies_ms.sort_by(|a, b| a.total_cmp(b));
    let pct = |q: f64| -> f64 {
        if latencies_ms.is_empty() {
            return 0.0;
        }
        let idx = ((latencies_ms.len() - 1) as f64 * q).round() as usize;
        latencies_ms[idx]
    };
    println!(
        "tenant={tenant} ops={n_ops} ok={ok} failed={failed} rejections={rejections} \
         elapsed_s={elapsed:.3} ops_per_sec={:.1} p50_ms={:.3} p99_ms={:.3} verified={verify}",
        n_ops as f64 / elapsed.max(1e-9),
        pct(0.50),
        pct(0.99),
    );
    let _ = client.bye();
    0
}

fn cmd_stats(args: &[String]) -> i32 {
    let mut client = match connect(args, "stats") {
        Ok(c) => c,
        Err(code) => return code,
    };
    match client.stats() {
        Ok(text) => {
            print!("{text}");
            let _ = client.bye();
            0
        }
        Err(e) => {
            eprintln!("stats failed: {e}");
            1
        }
    }
}

fn cmd_shutdown(args: &[String]) -> i32 {
    let client = match connect(args, "admin") {
        Ok(c) => c,
        Err(code) => return code,
    };
    match client.shutdown_daemon() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("shutdown failed: {e}");
            1
        }
    }
}

/// FNV-1a over the payload bytes — a cheap digest every survivor can
/// print so the smoke harness checks bit-identity with `sort -u`.
fn fnv1a(data: &[i64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in data {
        for b in v.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

fn cmd_rank(args: &[String]) -> i32 {
    let Some(dir) = opt(args, "--dir") else {
        eprintln!("need --dir DIR (shared rendezvous directory)");
        return 2;
    };
    let Some(my_rank) = opt(args, "--rank").and_then(|v| v.parse::<usize>().ok()) else {
        eprintln!("need --rank R");
        return 2;
    };
    let p = opt_usize(args, "-p", 0);
    if p == 0 || my_rank >= p {
        eprintln!("need -p N with 0 <= rank < N (got rank {my_rank}, p {p})");
        return 2;
    }
    let world_id = opt_u64(args, "--world-id", 1);
    let m = opt_usize(args, "--m", 4096);
    let mut root_g = opt_usize(args, "--root", 0);
    let blocks = opt_usize(args, "--blocks", 8);
    let crash_after = opt(args, "--crash-after").and_then(|v| v.parse::<usize>().ok());
    let timeout = Duration::from_millis(opt_u64(args, "--timeout-ms", 10_000));
    let max_shrinks = opt_usize(args, "--max-shrinks", 2);
    let seed = opt_u64(args, "--seed", 1);
    if root_g >= p {
        eprintln!("--root {root_g} out of range for p = {p}");
        return 2;
    }

    // Every process derives the payload from the shared seed, so the
    // root of *any* epoch can serve it and survivors can restart a
    // broadcast whose original root died.
    let data: Vec<i64> = Rng::new(seed.max(1)).vec_i64(m, -1_000_000, 1_000_000);
    let base = Path::new(dir);
    let mut membership = Membership::new(p);
    let mut shrinks = 0usize;

    loop {
        let epoch = membership.epoch();
        let pp = membership.p();
        let Some(rd) = membership.dense(my_rank) else {
            // Only reachable if this process was named dead by others
            // yet lived — a split verdict the smoke must surface.
            eprintln!("rank {my_rank}: voted out of epoch {epoch}, exiting");
            return 1;
        };
        let root_d = membership.dense(root_g).expect("elected root is a member");
        let edir = base.join(format!("epoch-{epoch}"));
        if let Err(e) = std::fs::create_dir_all(&edir) {
            eprintln!("rank {my_rank}: mkdir {}: {e}", edir.display());
            return 1;
        }
        let tr = match SocketTransport::<i64>::uds_world_epoch(
            rd, pp, world_id, epoch, &edir, timeout,
        ) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("rank {my_rank}: rendezvous failed (epoch {epoch}, p {pp}): {e}");
                return 1;
            }
        };
        let rc = RankComm::new(pp, rd, Arc::new(Skips::new(pp)));
        let mut buf = if rd == root_d { data.clone() } else { vec![0i64; data.len()] };

        if let Some(k) = crash_after {
            // This process is the designated victim: die at round `k`
            // without saying goodbye. `abort()` skips destructors, so
            // no BYE/ABORT frame is ever written — peers read raw EOF
            // on their direct links, the signature of a killed process.
            let mut dead = CrashAfter::new(tr, k);
            let _ = rc.bcast(&mut dead, root_d, &mut buf, blocks);
            std::process::abort();
        }

        let mut tr = tr;
        match rc.bcast(&mut tr, root_d, &mut buf, blocks) {
            Ok(_) => {
                println!(
                    "rank {my_rank} OK epoch {epoch} p {pp} digest {:016x}",
                    fnv1a(&buf)
                );
                return 0;
            }
            Err(e) => {
                // Let the reader threads drain the EOFs still in
                // flight, then harvest the link-accounting detector.
                std::thread::sleep(Duration::from_millis(500));
                let suspects_d = tr.failed_peers();
                drop(tr);
                if suspects_d.is_empty() {
                    eprintln!(
                        "rank {my_rank}: epoch {epoch} failed with no dead peer detected: {e}"
                    );
                    return 1;
                }
                if shrinks >= max_shrinks {
                    eprintln!(
                        "rank {my_rank}: shrink budget ({max_shrinks}) exhausted at epoch {epoch}"
                    );
                    return 1;
                }
                shrinks += 1;
                let suspects_g: Vec<usize> =
                    suspects_d.iter().map(|&d| membership.global(d)).collect();
                let (next, change) = membership.shrink(&suspects_g);
                eprintln!(
                    "rank {my_rank}: epoch {epoch} lost {:?}; rebuilding at p {} (epoch {})",
                    change.failed,
                    next.p(),
                    next.epoch()
                );
                membership = next;
                root_g = membership.elect_root(root_g);
            }
        }
    }
}
