//! Minimal property-testing harness — the offline substitute for
//! `proptest`/`quickcheck` (not in the vendored crate set; see DESIGN.md
//! §Substitutions).
//!
//! Provides a deterministic xorshift PRNG and a `forall` driver that, on
//! failure, retries with "shrunk" (halved) integer inputs to report a
//! small counterexample. Deterministic by default (fixed seed) so CI is
//! reproducible; set `TESTKIT_SEED` to explore — [`effective_seed`]
//! resolves the active seed, every `forall` failure prints it, and
//! [`install_seed_reporter`] appends it to arbitrary panic reports so
//! seed-matrix CI failures reproduce from the log alone.
//!
//! Also home to the seeded multi-collective workload generator
//! ([`TrafficMix`] / [`traffic_mix`]) and its batched/blocking adapters
//! ([`submit_mix_op`], [`run_mix_blocking`], [`MixOutcome`]) shared by
//! the differential traffic suite, the property tests and
//! `benches/traffic_mix.rs`.

/// The fixed default seed (used when `TESTKIT_SEED` is unset).
pub const DEFAULT_SEED: u64 = 0x9E3779B97F4A7C15;

/// The seed every `Rng::from_env` draw resolves to: `TESTKIT_SEED` if
/// set and parseable, else [`DEFAULT_SEED`]. Exposed so failure reports
/// can print the value that reproduces the run.
pub fn effective_seed() -> u64 {
    std::env::var("TESTKIT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_SEED)
}

/// Install a process-wide panic hook that appends the effective
/// `TESTKIT_SEED` to every panic report, so a CI seed-matrix failure is
/// reproducible from the log alone — call once at the top of any
/// seed-driven integration test (idempotent; chains to the previous
/// hook, so the original message is preserved).
pub fn install_seed_reporter() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            prev(info);
            eprintln!(
                "note: effective TESTKIT_SEED = {} (set TESTKIT_SEED to reproduce)",
                effective_seed()
            );
        }));
    });
}

/// xorshift64* PRNG — deterministic, seedable, no dependencies.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    /// Seed from `TESTKIT_SEED` or the fixed default.
    pub fn from_env() -> Self {
        Rng::new(effective_seed())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() as usize) % (hi - lo + 1)
    }

    /// Uniform i64 in `[lo, hi]`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// A vector of `len` i64 values in `[lo, hi]`.
    pub fn vec_i64(&mut self, len: usize, lo: i64, hi: i64) -> Vec<i64> {
        (0..len).map(|_| self.range_i64(lo, hi)).collect()
    }

    /// Biased coin.
    pub fn chance(&mut self, prob_num: u64, prob_den: u64) -> bool {
        self.next_u64() % prob_den < prob_num
    }
}

/// Run `prop` on `cases` random inputs drawn by `gen`; on failure, try to
/// shrink (halve all usize fields via the case's own `shrink`) and panic
/// with the smallest failing case found.
pub fn forall<C, G, P>(cases: usize, mut generate: G, mut prop: P)
where
    C: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> C,
    P: FnMut(&C) -> Result<(), String>,
{
    let mut rng = Rng::from_env();
    for i in 0..cases {
        let case = generate(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property failed on case #{i} (TESTKIT_SEED = {}): {case:?}\n  {msg}",
                effective_seed()
            );
        }
    }
}

/// `forall` with shrinking: `shrink` proposes smaller variants of a
/// failing case; the smallest still-failing one is reported.
pub fn forall_shrink<C, G, P, S>(cases: usize, mut generate: G, mut prop: P, shrink: S)
where
    C: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> C,
    P: FnMut(&C) -> Result<(), String>,
    S: Fn(&C) -> Vec<C>,
{
    let mut rng = Rng::from_env();
    for i in 0..cases {
        let case = generate(&mut rng);
        if let Err(first_msg) = prop(&case) {
            // Greedy shrink loop.
            let mut best = case.clone();
            let mut best_msg = first_msg;
            let mut progress = true;
            while progress {
                progress = false;
                for cand in shrink(&best) {
                    if let Err(msg) = prop(&cand) {
                        best = cand;
                        best_msg = msg;
                        progress = true;
                        break;
                    }
                }
            }
            panic!(
                "property failed on case #{i} (TESTKIT_SEED = {})\n  original: {case:?}\n  shrunk:   {best:?}\n  {best_msg}",
                effective_seed()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_in_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.range(3, 17);
            assert!((3..=17).contains(&v));
            let w = r.range_i64(-5, 5);
            assert!((-5..=5).contains(&w));
        }
    }

    #[test]
    fn forall_passes() {
        forall(
            100,
            |rng| rng.range(1, 100),
            |&n| if n >= 1 { Ok(()) } else { Err("n < 1".into()) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_fails_and_reports() {
        forall(
            100,
            |rng| rng.range(1, 100),
            |&n| if n < 50 { Ok(()) } else { Err(format!("n={n} too big")) },
        );
    }

    #[test]
    #[should_panic(expected = "shrunk")]
    fn shrinking_finds_smaller() {
        forall_shrink(
            10,
            |rng| rng.range(50, 100),
            |&n| if n < 10 { Ok(()) } else { Err(format!("n={n}")) },
            |&n| if n > 1 { vec![n / 2] } else { vec![] },
        );
    }
}

// ---------------------------------------------------------------------
// TrafficMix: the seeded multi-collective workload generator
// ---------------------------------------------------------------------

use std::sync::Arc;

use crate::collectives::SumOp;
use crate::comm::{
    Algo, AllgathervReq, AllreduceReq, BcastReq, CommError, Communicator, IallgathervReq,
    IallreduceReq, IbcastReq, IreduceReq, IreduceScatterReq, Kind, Outcome, Pending, ReduceReq,
    ReduceScatterReq, TrafficEngine,
};

/// One operation of a synthetic traffic mix: kind × window × root ×
/// size × block count × algorithm, with a private `data_seed` from
/// which the payloads are derived deterministically — so the batched
/// and the sequential side of a differential test (and the bench)
/// construct bit-identical inputs without sharing buffers.
#[derive(Debug, Clone)]
pub struct MixOp {
    pub kind: Kind,
    /// `(base, len)` machine-rank window; `None` = the whole machine.
    pub window: Option<(usize, usize)>,
    /// Window-local root (rooted collectives; ignored by the rest).
    pub root: usize,
    /// Payload scale in elements (total across roots/chunks for the
    /// all-collectives).
    pub m: usize,
    /// Explicit block count; `None` = the library's §3 rule (which is
    /// also what lets `Algo::Auto` fall back to the binomial tree for
    /// small rooted payloads).
    pub blocks: Option<usize>,
    pub algo: Algo,
    pub data_seed: u64,
}

impl MixOp {
    /// Ranks this op runs over on a `p`-rank machine.
    pub fn ranks(&self, p: usize) -> usize {
        self.window.map(|(_, len)| len).unwrap_or(p)
    }

    fn data_rng(&self) -> Rng {
        Rng::new(self.data_seed)
    }

    /// The broadcast payload (`m` elements).
    pub fn bcast_data(&self) -> Vec<i64> {
        self.data_rng().vec_i64(self.m, -999, 999)
    }

    /// Equal-length per-rank contributions (reduce / allreduce).
    pub fn equal_inputs(&self, ranks: usize) -> Vec<Vec<i64>> {
        let mut rng = self.data_rng();
        (0..ranks).map(|_| rng.vec_i64(self.m, -999, 999)).collect()
    }

    /// Irregular per-rank counts summing to roughly `m` (allgatherv) —
    /// zeros, spikes and ordinary values, like the paper's irregular
    /// problems.
    pub fn irregular_counts(&self, ranks: usize) -> Vec<usize> {
        let mut rng = self.data_rng();
        let cap = (2 * self.m / ranks.max(1)).max(1);
        (0..ranks)
            .map(|_| match rng.range(0, 3) {
                0 => 0,
                1 => rng.range(1, cap),
                _ => rng.range(1, 2 * cap),
            })
            .collect()
    }

    /// The allgatherv inputs matching [`MixOp::irregular_counts`].
    pub fn allgatherv_inputs(&self, ranks: usize) -> Vec<Vec<i64>> {
        let counts = self.irregular_counts(ranks);
        let mut rng = self.data_rng();
        for _ in 0..ranks {
            rng.next_u64(); // decorrelate from the counts draw
        }
        counts.iter().map(|&c| rng.vec_i64(c, -999, 999)).collect()
    }

    /// Reduce-scatter `(counts, inputs)`: per-destination counts (zeros
    /// allowed) and one full-length contribution per rank.
    pub fn reduce_scatter_shape(&self, ranks: usize) -> (Vec<usize>, Vec<Vec<i64>>) {
        let mut rng = self.data_rng();
        let cap = (2 * self.m / ranks.max(1)).max(1);
        let counts: Vec<usize> = (0..ranks).map(|_| rng.range(0, cap)).collect();
        let total: usize = counts.iter().sum();
        let inputs: Vec<Vec<i64>> = (0..ranks).map(|_| rng.vec_i64(total, -999, 999)).collect();
        (counts, inputs)
    }
}

/// A seeded multi-collective workload: `ops` in submission (arrival)
/// order over a `p`-rank machine. Shared by the differential traffic
/// suite, the property tests and `benches/traffic_mix.rs`.
#[derive(Debug, Clone)]
pub struct TrafficMix {
    pub p: usize,
    pub ops: Vec<MixOp>,
}

/// Knobs of [`traffic_mix`].
#[derive(Debug, Clone)]
pub struct MixOptions {
    /// Max payload scale per op (elements).
    pub max_m: usize,
    /// Max explicit block count.
    pub max_blocks: usize,
    /// Percent of ops restricted to a random sub-window (when `p > 1`).
    pub window_pct: u64,
    /// Percent of ops submitted with `Algo::Auto` (and no block
    /// override), exercising the small-payload binomial fallback.
    pub auto_pct: u64,
}

impl Default for MixOptions {
    fn default() -> Self {
        MixOptions { max_m: 48, max_blocks: 8, window_pct: 40, auto_pct: 25 }
    }
}

/// Draw a traffic mix: `n_ops` operations over all five collective
/// kinds, random roots/sizes/windows, in random arrival order.
/// Deterministic per `rng` state — `Rng::from_env` honours
/// `TESTKIT_SEED`.
pub fn traffic_mix(rng: &mut Rng, p: usize, n_ops: usize, opts: &MixOptions) -> TrafficMix {
    let ops = (0..n_ops)
        .map(|_| {
            let window = if p > 1 && rng.chance(opts.window_pct, 100) {
                let len = rng.range(1, p);
                Some((rng.range(0, p - len), len))
            } else {
                None
            };
            let ranks = window.map(|(_, len)| len).unwrap_or(p);
            let kind = match rng.range(0, 4) {
                0 => Kind::Bcast,
                1 => Kind::Reduce,
                2 => Kind::Allgatherv,
                3 => Kind::ReduceScatter,
                _ => Kind::Allreduce,
            };
            let auto = rng.chance(opts.auto_pct, 100);
            MixOp {
                kind,
                window,
                root: rng.range(0, ranks - 1),
                m: rng.range(0, opts.max_m),
                blocks: if auto { None } else { Some(rng.range(1, opts.max_blocks)) },
                algo: if auto { Algo::Auto } else { Algo::Circulant },
                data_seed: rng.next_u64(),
            }
        })
        .collect();
    TrafficMix { p, ops }
}

/// Uniform, comparable result of one mix op — buffers flattened to
/// rank-major `Vec<Vec<i64>>`, plus everything the differential suite
/// compares bit-for-bit (completion, resolved algorithm, rounds, the
/// full statistics; errors as their display string, which carries the
/// error kind and round).
#[derive(Debug, Clone, PartialEq)]
pub enum MixOutcome {
    Done {
        buffers: Vec<Vec<i64>>,
        complete: bool,
        algo: Algo,
        rounds: usize,
        active_rounds: usize,
        messages: usize,
        bytes: usize,
        max_rank_bytes: usize,
        time: f64,
    },
    Failed(String),
}

fn done<B>(out: Outcome<B>, flatten: impl FnOnce(B) -> Vec<Vec<i64>>) -> MixOutcome {
    MixOutcome::Done {
        complete: out.complete,
        algo: out.algo,
        rounds: out.rounds,
        active_rounds: out.stats.active_rounds,
        messages: out.stats.messages,
        bytes: out.stats.bytes,
        max_rank_bytes: out.stats.max_rank_bytes,
        time: out.stats.time,
        buffers: flatten(out.buffers),
    }
}

fn mix_outcome<B>(
    res: Result<Outcome<B>, CommError>,
    flatten: impl FnOnce(B) -> Vec<Vec<i64>>,
) -> MixOutcome {
    match res {
        Ok(out) => done(out, flatten),
        Err(e) => MixOutcome::Failed(format!("{e}")),
    }
}

fn flatten_rows(rows_per_rank: Vec<Vec<Vec<i64>>>) -> Vec<Vec<i64>> {
    rows_per_rank.into_iter().map(|rows| rows.into_iter().flatten().collect()).collect()
}

/// The typed handle of a submitted mix op (one variant per kind).
pub enum MixPending {
    Bcast(Pending<Vec<Vec<i64>>>),
    Reduce(Pending<Vec<i64>>),
    Allgatherv(Pending<Vec<Vec<Vec<i64>>>>),
    ReduceScatter(Pending<Vec<Vec<i64>>>),
    Allreduce(Pending<Vec<Vec<i64>>>),
}

impl MixPending {
    /// Take the batched result (after `TrafficEngine::run`).
    pub fn take(self) -> MixOutcome {
        match self {
            MixPending::Bcast(h) => mix_outcome(h.wait(), |b| b),
            MixPending::Reduce(h) => mix_outcome(h.wait(), |b| vec![b]),
            MixPending::Allgatherv(h) => mix_outcome(h.wait(), flatten_rows),
            MixPending::ReduceScatter(h) => mix_outcome(h.wait(), |b| b),
            MixPending::Allreduce(h) => mix_outcome(h.wait(), |b| b),
        }
    }
}

/// Submit one mix op into a batch (payloads derived from the op's
/// `data_seed`). Returns the typed handle; submission errors surface as
/// the `Err` they would be on the blocking path.
pub fn submit_mix_op(
    traffic: &mut TrafficEngine<'_>,
    op: &MixOp,
) -> Result<MixPending, CommError> {
    let p = traffic.comm().p();
    let ranks = op.ranks(p);
    macro_rules! opts {
        ($req:expr) => {{
            let mut req = $req.algo(op.algo);
            if let Some(n) = op.blocks {
                req = req.blocks(n);
            }
            if let Some((base, len)) = op.window {
                req = req.window(base, len);
            }
            req
        }};
    }
    Ok(match op.kind {
        Kind::Bcast => MixPending::Bcast(
            traffic.submit(opts!(IbcastReq::new(op.root, op.bcast_data())))?,
        ),
        Kind::Reduce => MixPending::Reduce(traffic.submit(opts!(IreduceReq::new(
            op.root,
            op.equal_inputs(ranks),
            Arc::new(SumOp)
        )))?),
        Kind::Allgatherv => MixPending::Allgatherv(
            traffic.submit(opts!(IallgathervReq::new(op.allgatherv_inputs(ranks))))?,
        ),
        Kind::ReduceScatter => {
            let (counts, inputs) = op.reduce_scatter_shape(ranks);
            MixPending::ReduceScatter(traffic.submit(opts!(IreduceScatterReq::new(
                inputs,
                counts,
                Arc::new(SumOp)
            )))?)
        }
        Kind::Allreduce => MixPending::Allreduce(traffic.submit(opts!(IallreduceReq::new(
            op.equal_inputs(ranks),
            Arc::new(SumOp)
        )))?),
    })
}

/// Run one mix op through the *blocking* API on `comm` (which must have
/// `p == op.ranks(machine_p)` — i.e. a fresh communicator of the op's
/// window size): the sequential side of the differential comparison.
pub fn run_mix_blocking(comm: &Communicator, op: &MixOp) -> MixOutcome {
    let ranks = comm.p();
    macro_rules! opts {
        ($req:expr) => {{
            let mut req = $req.algo(op.algo);
            if let Some(n) = op.blocks {
                req = req.blocks(n);
            }
            req
        }};
    }
    match op.kind {
        Kind::Bcast => {
            let data = op.bcast_data();
            mix_outcome(comm.bcast(opts!(BcastReq::new(op.root, &data))), |b| b)
        }
        Kind::Reduce => {
            let inputs = op.equal_inputs(ranks);
            mix_outcome(
                comm.reduce(opts!(ReduceReq::new(op.root, &inputs, Arc::new(SumOp)))),
                |b| vec![b],
            )
        }
        Kind::Allgatherv => {
            let inputs = op.allgatherv_inputs(ranks);
            mix_outcome(comm.allgatherv(opts!(AllgathervReq::new(&inputs))), flatten_rows)
        }
        Kind::ReduceScatter => {
            let (counts, inputs) = op.reduce_scatter_shape(ranks);
            mix_outcome(
                comm.reduce_scatter(opts!(ReduceScatterReq::new(
                    &inputs,
                    &counts,
                    Arc::new(SumOp)
                ))),
                |b| b,
            )
        }
        Kind::Allreduce => {
            let inputs = op.equal_inputs(ranks);
            mix_outcome(comm.allreduce(opts!(AllreduceReq::new(&inputs, Arc::new(SumOp)))), |b| b)
        }
    }
}
