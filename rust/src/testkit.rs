//! Minimal property-testing harness — the offline substitute for
//! `proptest`/`quickcheck` (not in the vendored crate set; see DESIGN.md
//! §Substitutions).
//!
//! Provides a deterministic xorshift PRNG and a `forall` driver that, on
//! failure, retries with "shrunk" (halved) integer inputs to report a
//! small counterexample. Deterministic by default (fixed seed) so CI is
//! reproducible; set `TESTKIT_SEED` to explore.

/// xorshift64* PRNG — deterministic, seedable, no dependencies.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    /// Seed from `TESTKIT_SEED` or the fixed default.
    pub fn from_env() -> Self {
        let seed = std::env::var("TESTKIT_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x9E3779B97F4A7C15);
        Rng::new(seed)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() as usize) % (hi - lo + 1)
    }

    /// Uniform i64 in `[lo, hi]`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// A vector of `len` i64 values in `[lo, hi]`.
    pub fn vec_i64(&mut self, len: usize, lo: i64, hi: i64) -> Vec<i64> {
        (0..len).map(|_| self.range_i64(lo, hi)).collect()
    }

    /// Biased coin.
    pub fn chance(&mut self, prob_num: u64, prob_den: u64) -> bool {
        self.next_u64() % prob_den < prob_num
    }
}

/// Run `prop` on `cases` random inputs drawn by `gen`; on failure, try to
/// shrink (halve all usize fields via the case's own `shrink`) and panic
/// with the smallest failing case found.
pub fn forall<C, G, P>(cases: usize, mut generate: G, mut prop: P)
where
    C: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> C,
    P: FnMut(&C) -> Result<(), String>,
{
    let mut rng = Rng::from_env();
    for i in 0..cases {
        let case = generate(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!("property failed on case #{i}: {case:?}\n  {msg}");
        }
    }
}

/// `forall` with shrinking: `shrink` proposes smaller variants of a
/// failing case; the smallest still-failing one is reported.
pub fn forall_shrink<C, G, P, S>(cases: usize, mut generate: G, mut prop: P, shrink: S)
where
    C: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> C,
    P: FnMut(&C) -> Result<(), String>,
    S: Fn(&C) -> Vec<C>,
{
    let mut rng = Rng::from_env();
    for i in 0..cases {
        let case = generate(&mut rng);
        if let Err(first_msg) = prop(&case) {
            // Greedy shrink loop.
            let mut best = case.clone();
            let mut best_msg = first_msg;
            let mut progress = true;
            while progress {
                progress = false;
                for cand in shrink(&best) {
                    if let Err(msg) = prop(&cand) {
                        best = cand;
                        best_msg = msg;
                        progress = true;
                        break;
                    }
                }
            }
            panic!(
                "property failed on case #{i}\n  original: {case:?}\n  shrunk:   {best:?}\n  {best_msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_in_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.range(3, 17);
            assert!((3..=17).contains(&v));
            let w = r.range_i64(-5, 5);
            assert!((-5..=5).contains(&w));
        }
    }

    #[test]
    fn forall_passes() {
        forall(
            100,
            |rng| rng.range(1, 100),
            |&n| if n >= 1 { Ok(()) } else { Err("n < 1".into()) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_fails_and_reports() {
        forall(
            100,
            |rng| rng.range(1, 100),
            |&n| if n < 50 { Ok(()) } else { Err(format!("n={n} too big")) },
        );
    }

    #[test]
    #[should_panic(expected = "shrunk")]
    fn shrinking_finds_smaller() {
        forall_shrink(
            10,
            |rng| rng.range(50, 100),
            |&n| if n < 10 { Ok(()) } else { Err(format!("n={n}")) },
            |&n| if n > 1 { vec![n / 2] } else { vec![] },
        );
    }
}
