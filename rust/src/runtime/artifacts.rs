//! Artifact discovery: find and describe the AOT-compiled HLO text
//! modules produced by `python -m compile.aot` (see `python/compile/aot.py`
//! for the naming convention, which is the contract between the layers):
//!
//! ```text
//! <fn>.<op>.<dtype>.<shape>.hlo.txt
//! pair.sum.f32.4096.hlo.txt       stack.sum.f32.8x4096.hlo.txt
//! ```

use std::path::{Path, PathBuf};

/// Which Layer-2 function an artifact encodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FnKind {
    /// `reduce_pair(a, b)` — two inputs, one output.
    Pair,
    /// `reduce_stack(xs[w, m])` — one input, one output.
    Stack,
    /// `reduce_pair_vjp(a, b)` — two inputs, three outputs.
    PairVjp,
}

/// Element type of an artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
}

/// One discovered artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Artifact {
    pub kind: FnKind,
    /// Operator name ("sum", "max", ...).
    pub op: String,
    pub dtype: DType,
    /// `[m]` for pair/pair_vjp, `[w, m]` for stack.
    pub shape: Vec<usize>,
    pub path: PathBuf,
}

impl Artifact {
    /// Block length `m` (the trailing dimension).
    pub fn block_len(&self) -> usize {
        *self.shape.last().unwrap()
    }

    /// Parse the artifact filename convention; `None` for foreign files.
    pub fn parse(path: &Path) -> Option<Artifact> {
        let name = path.file_name()?.to_str()?;
        let stem = name.strip_suffix(".hlo.txt")?;
        let parts: Vec<&str> = stem.split('.').collect();
        if parts.len() != 4 {
            return None;
        }
        let kind = match parts[0] {
            "pair" => FnKind::Pair,
            "stack" => FnKind::Stack,
            "pair_vjp" => FnKind::PairVjp,
            _ => return None,
        };
        let op = parts[1].to_string();
        let dtype = match parts[2] {
            "f32" => DType::F32,
            "i32" => DType::I32,
            _ => return None,
        };
        let shape: Vec<usize> = parts[3]
            .split('x')
            .map(|s| s.parse().ok())
            .collect::<Option<Vec<_>>>()?;
        let want_dims = if kind == FnKind::Stack { 2 } else { 1 };
        if shape.len() != want_dims {
            return None;
        }
        Some(Artifact { kind, op, dtype, shape, path: path.to_path_buf() })
    }
}

/// Scan a directory for artifacts.
pub fn discover(dir: &Path) -> std::io::Result<Vec<Artifact>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        if let Some(a) = Artifact::parse(&entry.path()) {
            out.push(a);
        }
    }
    out.sort_by(|a, b| a.path.cmp(&b.path));
    Ok(out)
}

/// The default artifacts directory: `$CBCAST_ARTIFACTS` or `./artifacts`
/// (relative to the workspace root when run via cargo).
pub fn default_dir() -> PathBuf {
    if let Ok(d) = std::env::var("CBCAST_ARTIFACTS") {
        return PathBuf::from(d);
    }
    // Prefer the manifest-relative location so tests/benches work from
    // any cwd inside the workspace.
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if manifest.exists() {
        return manifest;
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_pair() {
        let a = Artifact::parse(Path::new("pair.sum.f32.4096.hlo.txt")).unwrap();
        assert_eq!(a.kind, FnKind::Pair);
        assert_eq!(a.op, "sum");
        assert_eq!(a.dtype, DType::F32);
        assert_eq!(a.shape, vec![4096]);
        assert_eq!(a.block_len(), 4096);
    }

    #[test]
    fn parse_stack() {
        let a = Artifact::parse(Path::new("/x/stack.max.i32.8x1024.hlo.txt")).unwrap();
        assert_eq!(a.kind, FnKind::Stack);
        assert_eq!(a.shape, vec![8, 1024]);
        assert_eq!(a.block_len(), 1024);
    }

    #[test]
    fn parse_rejects_foreign() {
        assert!(Artifact::parse(Path::new("manifest.json")).is_none());
        assert!(Artifact::parse(Path::new("pair.sum.f32.hlo.txt")).is_none());
        assert!(Artifact::parse(Path::new("what.sum.f32.64.hlo.txt")).is_none());
        assert!(Artifact::parse(Path::new("pair.sum.f99.64.hlo.txt")).is_none());
        assert!(Artifact::parse(Path::new("stack.sum.f32.64.hlo.txt")).is_none());
    }
}
