//! The PJRT runtime bridge: load the AOT-compiled HLO text artifacts
//! (authored by JAX/Pallas at build time, see `python/compile/`) and
//! execute them from the Rust hot path. Python is never on the request
//! path — the `cbcast` binary is self-contained once `make artifacts`
//! has run.

pub mod artifacts;
pub mod executor;

pub use artifacts::{discover, default_dir, Artifact, DType, FnKind};
pub use executor::{XlaRuntime, XlaSumOp};
