//! The PJRT runtime bridge: load the AOT-compiled HLO text artifacts
//! (authored by JAX/Pallas at build time, see `python/compile/`) and
//! execute them from the Rust hot path. Python is never on the request
//! path — the `cbcast` binary is self-contained once `make artifacts`
//! has run.
//!
//! The executor needs the external `xla` crate and is therefore gated
//! behind the `xla` cargo feature (the offline build image cannot fetch
//! it). Without the feature a stub with the same API compiles in; it
//! reports itself unavailable at runtime and every caller degrades
//! gracefully (see `rust/Cargo.toml` for how to enable the real thing).

pub mod artifacts;

#[cfg(feature = "xla")]
pub mod executor;

#[cfg(not(feature = "xla"))]
#[path = "stub.rs"]
pub mod executor;

pub use artifacts::{default_dir, discover, Artifact, DType, FnKind};
pub use executor::{XlaRuntime, XlaSumOp};
