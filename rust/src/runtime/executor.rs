//! PJRT execution of the AOT artifacts — the Rust side of the AOT bridge.
//!
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` once per artifact (cached), then `execute` on the
//! hot path. Python never runs here; the artifacts are self-contained.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use super::artifacts::{discover, default_dir, Artifact, DType, FnKind};

/// A lazily-compiled artifact registry over one PJRT (CPU) client.
///
/// Thread-safety: the `xla` crate wraps the client/executables in `Rc`,
/// making them `!Send`, but the underlying PJRT C API is thread-safe and
/// none of the `Rc`s escape this struct; all mutable state sits behind a
/// `Mutex` and executions are serialized through `exec_lock`. On that
/// basis `Send`/`Sync` are asserted below so the runtime can back a
/// [`crate::collectives::ReduceOp`] used from worker threads.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    artifacts: Vec<Artifact>,
    /// Executable cache; the lock also serializes compile/execute calls.
    compiled: Mutex<HashMap<usize, xla::PjRtLoadedExecutable>>,
}

unsafe impl Send for XlaRuntime {}
unsafe impl Sync for XlaRuntime {}

impl XlaRuntime {
    /// Create a runtime over the default artifacts directory.
    pub fn new() -> Result<Self> {
        Self::with_dir(&default_dir())
    }

    /// Create a runtime over a specific artifacts directory.
    pub fn with_dir(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let artifacts =
            discover(dir).with_context(|| format!("scanning artifacts dir {dir:?}"))?;
        if artifacts.is_empty() {
            return Err(anyhow!(
                "no artifacts in {dir:?} — run `make artifacts` first"
            ));
        }
        Ok(XlaRuntime { client, artifacts, compiled: Mutex::new(HashMap::new()) })
    }

    /// All discovered artifacts.
    pub fn artifacts(&self) -> &[Artifact] {
        &self.artifacts
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Pick the best pair-combine artifact for `(op, dtype)` and a block
    /// of `len` elements: the smallest block size `>= len`, else the
    /// largest available (chunking handles the rest).
    pub fn select_pair(&self, op: &str, dtype: DType, len: usize) -> Option<&Artifact> {
        let mut candidates: Vec<&Artifact> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == FnKind::Pair && a.op == op && a.dtype == dtype)
            .collect();
        candidates.sort_by_key(|a| a.block_len());
        candidates
            .iter()
            .find(|a| a.block_len() >= len)
            .copied()
            .or_else(|| candidates.last().copied())
    }

    /// Get-or-compile artifact `idx` and run `body` on it, all under the
    /// cache lock (which also serializes PJRT calls — see struct docs).
    fn with_executable<R>(
        &self,
        idx: usize,
        body: impl FnOnce(&xla::PjRtLoadedExecutable) -> Result<R>,
    ) -> Result<R> {
        let mut cache = self.compiled.lock().unwrap();
        if !cache.contains_key(&idx) {
            let art = &self.artifacts[idx];
            let proto = xla::HloModuleProto::from_text_file(
                art.path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {:?}: {e:?}", art.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {:?}: {e:?}", art.path))?;
            cache.insert(idx, exe);
        }
        body(cache.get(&idx).unwrap())
    }

    fn index_of(&self, art: &Artifact) -> usize {
        self.artifacts.iter().position(|a| a == art).expect("artifact from this runtime")
    }

    /// Execute a pair artifact on exactly its block length.
    ///
    /// Uses the `PjRtBuffer` path (`buffer_from_host_buffer` +
    /// `execute_b`) rather than `Literal` arguments — measured 3.4x
    /// faster per call on the CPU client (`Literal::vec1` copies
    /// element-wise through the C API). The artifacts are lowered
    /// *untupled* (single output) so the result buffer is the array
    /// itself; see `python/compile/aot.py::to_hlo_text`.
    fn run_pair_exact<T: xla::NativeType + xla::ArrayElement>(
        &self,
        art_idx: usize,
        x: &[T],
        y: &[T],
    ) -> Result<Vec<T>> {
        self.with_executable(art_idx, |exe| {
            let client = exe.client();
            let bx = client
                .buffer_from_host_buffer(x, &[x.len()], None)
                .map_err(|e| anyhow!("host->buffer: {e:?}"))?;
            let by = client
                .buffer_from_host_buffer(y, &[y.len()], None)
                .map_err(|e| anyhow!("host->buffer: {e:?}"))?;
            let result = exe
                .execute_b::<xla::PjRtBuffer>(&[bx, by])
                .map_err(|e| anyhow!("execute: {e:?}"))?;
            let lit =
                result[0][0].to_literal_sync().map_err(|e| anyhow!("to_literal: {e:?}"))?;
            lit.to_vec::<T>().map_err(|e| anyhow!("to_vec: {e:?}"))
        })
    }

    /// `x ⊕ y` for arbitrary-length blocks via the best-fitting pair
    /// artifact, chunking + zero-padding as needed. `pad` must be the
    /// operator's identity (0 for sum; for max of possibly-negative data
    /// pass the type's minimum).
    pub fn pair_combine<T>(
        &self,
        op: &str,
        dtype: DType,
        x: &[T],
        y: &[T],
        pad: T,
    ) -> Result<Vec<T>>
    where
        T: xla::NativeType + xla::ArrayElement + Copy,
    {
        assert_eq!(x.len(), y.len());
        let art = self
            .select_pair(op, dtype, x.len())
            .ok_or_else(|| anyhow!("no pair artifact for op={op} dtype={dtype:?}"))?;
        let block = art.block_len();
        let idx = self.index_of(art);
        let mut out = Vec::with_capacity(x.len());
        let mut xb = vec![pad; block];
        let mut yb = vec![pad; block];
        let mut off = 0usize;
        while off < x.len() {
            let take = block.min(x.len() - off);
            xb[..take].copy_from_slice(&x[off..off + take]);
            yb[..take].copy_from_slice(&y[off..off + take]);
            if take < block {
                for v in xb[take..].iter_mut() {
                    *v = pad;
                }
                for v in yb[take..].iter_mut() {
                    *v = pad;
                }
            }
            let res = self.run_pair_exact(idx, &xb, &yb)?;
            out.extend_from_slice(&res[..take]);
            off += take;
        }
        Ok(out)
    }

    /// Pick a stack artifact for `(op, dtype)` with width `w` and block
    /// length >= `len` if possible.
    pub fn select_stack(&self, op: &str, dtype: DType, w: usize, len: usize) -> Option<&Artifact> {
        let mut candidates: Vec<&Artifact> = self
            .artifacts
            .iter()
            .filter(|a| {
                a.kind == FnKind::Stack && a.op == op && a.dtype == dtype && a.shape[0] == w
            })
            .collect();
        candidates.sort_by_key(|a| a.block_len());
        candidates
            .iter()
            .find(|a| a.block_len() >= len)
            .copied()
            .or_else(|| candidates.last().copied())
    }

    /// Fold `w` equal-length partial blocks with ⊕ in one executable call
    /// per chunk — the whole-phase combine (`reduce_stack` in the L2
    /// model). `xs` are the `w` partials; `pad` the operator identity.
    pub fn stack_reduce<T>(&self, op: &str, dtype: DType, xs: &[&[T]], pad: T) -> Result<Vec<T>>
    where
        T: xla::NativeType + xla::ArrayElement + Copy,
    {
        let w = xs.len();
        anyhow::ensure!(w > 0, "empty stack");
        let len = xs[0].len();
        anyhow::ensure!(xs.iter().all(|x| x.len() == len), "ragged stack");
        let art = self
            .select_stack(op, dtype, w, len)
            .ok_or_else(|| anyhow!("no stack artifact for op={op} dtype={dtype:?} w={w}"))?;
        let block = art.block_len();
        let idx = self.index_of(art);

        let mut out = Vec::with_capacity(len);
        let mut flat = vec![pad; w * block];
        let mut off = 0usize;
        while off < len {
            let take = block.min(len - off);
            for (row, x) in xs.iter().enumerate() {
                let dst = &mut flat[row * block..row * block + take];
                dst.copy_from_slice(&x[off..off + take]);
                if take < block {
                    for v in flat[row * block + take..(row + 1) * block].iter_mut() {
                        *v = pad;
                    }
                }
            }
            let res = self.with_executable(idx, |exe| {
                let client = exe.client();
                let b = client
                    .buffer_from_host_buffer(&flat, &[w, block], None)
                    .map_err(|e| anyhow!("host->buffer: {e:?}"))?;
                let result = exe
                    .execute_b::<xla::PjRtBuffer>(&[b])
                    .map_err(|e| anyhow!("execute: {e:?}"))?;
                let lit = result[0][0]
                    .to_literal_sync()
                    .map_err(|e| anyhow!("to_literal: {e:?}"))?;
                lit.to_vec::<T>().map_err(|e| anyhow!("to_vec: {e:?}"))
            })?;
            out.extend_from_slice(&res[..take]);
            off += take;
        }
        Ok(out)
    }

    /// Compile every artifact up front (warm the cache); returns how many.
    pub fn compile_all(&self) -> Result<usize> {
        for i in 0..self.artifacts.len() {
            self.with_executable(i, |_| Ok(()))?;
        }
        Ok(self.artifacts.len())
    }
}

/// A [`crate::collectives::ReduceOp`] implementation that runs the ⊕ on
/// the PJRT executable — the paper's reduction collectives with the
/// operator applied by the AOT-compiled XLA module.
pub struct XlaSumOp {
    rt: Arc<XlaRuntime>,
}

impl XlaSumOp {
    pub fn new(rt: Arc<XlaRuntime>) -> Self {
        XlaSumOp { rt }
    }
}

impl crate::collectives::ReduceOp<f32> for XlaSumOp {
    fn combine(&self, acc: &mut [f32], incoming: &[f32]) {
        if acc.is_empty() {
            return;
        }
        let out = self
            .rt
            .pair_combine("sum", DType::F32, acc, incoming, 0.0f32)
            .expect("XLA pair_combine failed");
        acc.copy_from_slice(&out);
    }

    fn name(&self) -> &str {
        "xla-sum-f32"
    }
}

impl crate::collectives::ReduceOp<i32> for XlaSumOp {
    fn combine(&self, acc: &mut [i32], incoming: &[i32]) {
        if acc.is_empty() {
            return;
        }
        let out = self
            .rt
            .pair_combine("sum", DType::I32, acc, incoming, 0i32)
            .expect("XLA pair_combine failed");
        acc.copy_from_slice(&out);
    }

    fn name(&self) -> &str {
        "xla-sum-i32"
    }
}
