//! Stub PJRT runtime, compiled when the `xla` feature is off (the
//! offline build image has no `xla`/`anyhow` crates).
//!
//! Mirrors the API surface of [`super::executor`] so every caller — the
//! `cbcast artifacts` command, the XLA examples, the XLA-backed
//! [`crate::collectives::ReduceOp`] — type-checks unchanged; construction
//! always fails with [`RuntimeUnavailable`], and callers that already
//! handle the "artifacts missing" error path degrade gracefully.

use std::path::Path;
use std::sync::Arc;

use super::artifacts::{Artifact, DType};

/// Error returned by every constructor of the stub runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RuntimeUnavailable;

impl std::fmt::Display for RuntimeUnavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "XLA runtime unavailable: built without the `xla` feature \
             (see rust/Cargo.toml [features] for how to enable it)"
        )
    }
}

impl std::error::Error for RuntimeUnavailable {}

/// Unconstructible stand-in for the PJRT artifact runtime.
pub struct XlaRuntime {
    _unconstructible: (),
}

impl XlaRuntime {
    /// Always fails: the `xla` feature is off.
    pub fn new() -> Result<Self, RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }

    /// Always fails: the `xla` feature is off.
    pub fn with_dir(_dir: &Path) -> Result<Self, RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }

    pub fn artifacts(&self) -> &[Artifact] {
        &[]
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn select_pair(&self, _op: &str, _dtype: DType, _len: usize) -> Option<&Artifact> {
        None
    }

    pub fn select_stack(
        &self,
        _op: &str,
        _dtype: DType,
        _w: usize,
        _len: usize,
    ) -> Option<&Artifact> {
        None
    }

    pub fn pair_combine<T: Copy>(
        &self,
        _op: &str,
        _dtype: DType,
        _x: &[T],
        _y: &[T],
        _pad: T,
    ) -> Result<Vec<T>, RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }

    pub fn stack_reduce<T: Copy>(
        &self,
        _op: &str,
        _dtype: DType,
        _xs: &[&[T]],
        _pad: T,
    ) -> Result<Vec<T>, RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }

    pub fn compile_all(&self) -> Result<usize, RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }
}

/// Stand-in for the XLA-executed ⊕. Constructible only from an
/// [`XlaRuntime`], which itself cannot be constructed without the `xla`
/// feature — so `combine` is statically unreachable.
pub struct XlaSumOp {
    _rt: Arc<XlaRuntime>,
}

impl XlaSumOp {
    pub fn new(rt: Arc<XlaRuntime>) -> Self {
        XlaSumOp { _rt: rt }
    }
}

impl crate::collectives::ReduceOp<f32> for XlaSumOp {
    fn combine(&self, _acc: &mut [f32], _incoming: &[f32]) {
        unreachable!("XlaRuntime is unconstructible without the `xla` feature")
    }

    fn name(&self) -> &str {
        "xla-sum-f32(unavailable)"
    }
}

impl crate::collectives::ReduceOp<i32> for XlaSumOp {
    fn combine(&self, _acc: &mut [i32], _incoming: &[i32]) {
        unreachable!("XlaRuntime is unconstructible without the `xla` feature")
    }

    fn name(&self) -> &str {
        "xla-sum-i32(unavailable)"
    }
}
